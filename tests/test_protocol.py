"""End-to-end SL protocol: real split fine-tuning converges (Eq. 1) and the
fleet simulator reproduces the paper's qualitative findings (Sec. V)."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.channel import WirelessChannel
from repro.core.hardware import EDGE_FLEET, SERVER_RTX4060TI, SimParams
from repro.core.protocol import SplitFineTuner
from repro.core.scheduler import compare_policies, simulate_fleet
from repro.data import make_fleet_datasets
from repro.models import model as M
from repro.launch.train import run_training
from repro.optim import adamw, constant_schedule


@pytest.fixture(scope="module")
def pretrained():
    """A briefly pre-trained tiny backbone (the 'pre-trained LLM')."""
    out = run_training(arch="llama32-1b", steps=0, pretrain_steps=80,
                       batch=8, seq_len=64, log_every=0)
    return out["cfg"], out["frozen"]


def test_split_finetuning_converges(pretrained):
    cfg, frozen = pretrained
    lora = M.init_params(jax.random.PRNGKey(3), cfg)["lora"]
    datasets = make_fleet_datasets(cfg, 2, vocab=cfg.vocab_size, seed=1)
    sim = SimParams(local_epochs=2, mini_batch=8, seq_len=64)
    ft = SplitFineTuner(cfg, frozen, lora, adamw(constant_schedule(3e-3)),
                        devices=list(EDGE_FLEET[:2]), server=SERVER_RTX4060TI,
                        channels=[WirelessChannel("normal", seed=i)
                                  for i in range(2)],
                        datasets=datasets, sim=sim, policy="card")
    res = ft.run(6)
    losses = res.losses()
    first = np.mean(losses[:3])
    last = np.mean(losses[-3:])
    assert last < first - 0.05, f"no convergence: {first:.3f} -> {last:.3f}"
    assert all(l.cut in range(0, cfg.n_layers + 1) for l in res.logs)


def test_policies_order_delay_energy():
    """Fig. 4 qualitative: device-only slowest, server-only most energy;
    CARD in between on both axes."""
    cfg = get_config("llama32-1b")
    logs = {p: simulate_fleet(cfg, policy=p, channel_state="normal",
                              rounds=12, seed=3)
            for p in ("card", "server_only", "device_only")}
    assert logs["card"].mean_delay() < logs["device_only"].mean_delay()
    assert logs["card"].mean_energy() < logs["server_only"].mean_energy()
    assert logs["server_only"].mean_delay() <= logs["card"].mean_delay()
    assert logs["device_only"].mean_energy() <= logs["card"].mean_energy()


def test_paper_headline_reductions():
    """Abstract: 70.8% delay cut vs device-only, 53.1% energy cut vs
    server-only. Our constants differ where the paper under-specifies
    (distance, bandwidth), so assert the reductions are large (>=40%),
    the right sign, and log the exact figures in benchmarks/fig4."""
    cfg = get_config("llama32-1b")
    card = simulate_fleet(cfg, policy="card", rounds=20, seed=0)
    dev = simulate_fleet(cfg, policy="device_only", rounds=20, seed=0)
    srv = simulate_fleet(cfg, policy="server_only", rounds=20, seed=0)
    delay_red = 1 - card.mean_delay() / dev.mean_delay()
    energy_red = 1 - card.mean_energy() / srv.mean_energy()
    assert delay_red >= 0.40, f"delay reduction only {delay_red:.1%}"
    assert energy_red >= 0.40, f"energy reduction only {energy_red:.1%}"


def test_channel_state_degrades_delay():
    cfg = get_config("llama32-1b")
    delays = [simulate_fleet(cfg, policy="card", channel_state=s,
                             rounds=10, seed=2).mean_delay()
              for s in ("good", "normal", "poor")]
    assert delays[0] <= delays[1] <= delays[2]


def test_cut_decisions_bimodal_in_simulation():
    """Fig. 3(a): with uniform decoder layers the chosen cuts concentrate
    on the endpoints {0, I}."""
    cfg = get_config("llama32-1b")
    log = simulate_fleet(cfg, policy="card", rounds=30, seed=1,
                         respect_memory=False)
    cuts = set(np.unique(log.cuts))
    assert cuts <= {0, cfg.n_layers}


def test_compare_policies_grid_shape():
    cfg = get_config("llama32-1b")
    grid = compare_policies(cfg, rounds=3, channel_states=("good",))
    assert set(grid) == {"card", "server_only", "device_only"}
    assert grid["card"]["good"].cuts.shape == (3, 5)


def test_parallel_round_stats_bounds():
    """Beyond-paper parallel-SL analysis: bounds are ordered and finite."""
    from repro.core.scheduler import parallel_round_stats
    cfg = get_config("llama32-1b")
    log = simulate_fleet(cfg, policy="card", rounds=5, seed=0)
    st = parallel_round_stats(log)
    assert st["parallel_lower_s"] <= st["sequential_s"]
    assert st["parallel_lower_s"] <= st["parallel_upper_s"]
    assert st["speedup_ub"] >= st["speedup_lb"] > 0
