"""End-to-end system tests: training driver, generation, distributed
lowering (subprocess with 512 host devices), shard_map MoE equivalence."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_py(code: str, timeout_s=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout_s,
                          env=env)


def test_training_driver_learns():
    from repro.launch.train import run_training
    out = run_training(arch="llama32-1b", steps=60, batch=8, seq_len=64,
                       lr=5e-3, log_every=0, pretrain_steps=50)
    # pretraining reaches a learnable region; LoRA fine-tuning then improves
    first = np.mean(out["losses"][:10])
    last = np.mean(out["losses"][-10:])
    assert last < first, f"LoRA phase did not improve: {first} -> {last}"


def test_generation_roundtrip():
    from repro.configs.base import get_config
    from repro.launch.serve import generate
    from repro.models import model as M
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                cfg.vocab_size)
    toks = generate(cfg, params["frozen"], params["lora"], prompt, 6)
    assert toks.shape == (2, 6)
    assert int(toks.min()) >= 0 and int(toks.max()) < cfg.vocab_size


@pytest.mark.slow
def test_dryrun_lowering_subprocess():
    """The multi-pod dry-run must lower on the 512-device mesh (smallest
    arch x decode shape; the full 40x2 matrix runs via the dryrun CLI)."""
    r = _run_py("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import lower_combo
        rec = lower_combo("qwen3-0.6b", "decode_32k", multi_pod=True,
                          compile_=False)
        print("OK" if rec["ok"] else "BAD")
    """)
    assert "OK" in r.stdout, r.stderr[-2000:]


@pytest.mark.slow
def test_shard_map_moe_matches_reference_subprocess():
    r = _run_py("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, dataclasses
        from repro import shardctx
        from repro.configs.base import get_config
        from repro.models import moe as moe_mod
        from repro.models import moe_shard_map as msm
        cfg = dataclasses.replace(get_config("kimi-k2-1t-a32b").reduced(),
                                  n_experts=8, top_k=2, d_ff=32, d_model=64,
                                  n_shared_experts=1, capacity_factor=4.0)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model)) * .5
        ref, _ = moe_mod.moe_forward(params, None, x, cfg)
        with mesh, shardctx.mesh_ctx(mesh):
            strat = msm.select_strategy(cfg)
            assert strat == "ep_a2a", strat
            out, _ = jax.jit(lambda p, v: msm.moe_forward_dist(
                p, None, v, cfg, strat))(params, x)
        err = float(jnp.max(jnp.abs(out - ref)))
        print("ERR", err)
        assert err < 1e-5
        print("OK")
    """)
    assert "OK" in r.stdout, (r.stdout, r.stderr[-2000:])


def test_mesh_factory_shapes():
    """make_production_mesh contract (shape/axes), without touching devices."""
    import inspect
    from repro.launch import mesh as mesh_mod
    src = inspect.getsource(mesh_mod.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '("pod", "data", "model")' in src


def test_hlo_collective_parser():
    from repro.launch.analysis import parse_collectives
    hlo = """
      %ag = bf16[2048,512]{1,0} all-gather(%x), dimensions={0}
      %ar.1 = f32[128]{0} all-reduce(%y), to_apply=%sum
      %a2a = (f32[16,8]{1,0}, f32[16,8]{1,0}) all-to-all(%a, %b)
      %cp = u32[4]{0} collective-permute(%z), source_target_pairs={{0,1}}
      %dot = f32[8,8]{1,0} dot(%p, %q)
    """
    stats = parse_collectives(hlo)
    assert stats.counts == {"all-gather": 1, "all-reduce": 1,
                            "all-to-all": 1, "collective-permute": 1}
    assert stats.bytes_by_kind["all-gather"] == 2048 * 512 * 2
    assert stats.bytes_by_kind["all-to-all"] == 2 * 16 * 8 * 4
    assert stats.total_bytes > 0


def test_roofline_terms_math():
    from repro.launch.analysis import Roofline
    r = Roofline(flops=197e12, hbm_bytes=819e9, collective_bytes=200e9,
                 chips=256)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(1.0)
    r2 = Roofline(flops=1e12, hbm_bytes=819e9 * 5, collective_bytes=0,
                  chips=256)
    assert r2.dominant == "memory"
