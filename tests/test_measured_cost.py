"""Measured cost model: roofline fit, latency tables, and the guarantee
that the "measured" backend is a strict generalization of the analytic one.

The load-bearing property: a ``LatencyTable`` synthesized *from* the
analytic model (``from_analytic``) must reproduce the analytic
``card``/``batched_card`` decisions exactly — same cuts, same Eq. 16
frequencies, same delays/energies — across architectures, channel states,
and both fleet engines. Everything the measured path changes is then
attributable to the calibration, not to the plumbing.
"""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.cost_model import (BatchedRoundContext, RoundContext,
                                   Workload, resolve_compute)
from repro.core.hardware import (DEFAULT_SIM, EDGE_FLEET, SERVER_RTX4060TI,
                                 profile_from_throughput)
from repro.core.measured_cost import (LatencyTable, ProbeResult, RooflineFit,
                                      TableCompute, build_latency_tables,
                                      fit_roofline)
from repro.core.scheduler import simulate_fleet

ARCHS = ("llama32-1b", "qwen3-4b", "granite-moe-3b-a800m")
STATES = ("good", "normal", "poor")

BATCH, SEQ = DEFAULT_SIM.mini_batch, DEFAULT_SIM.seq_len


def _analytic_table(arch):
    return LatencyTable.from_analytic(
        Workload(get_config(arch), BATCH, SEQ))


def _synthetic_fit(backend="jnp"):
    """A plausible edge-host roofline, no probing needed."""
    return RooflineFit(inv_compute=1e-11, inv_bandwidth=2e-11,
                       overhead_s=1e-4, achieved_flops_per_s=8e10,
                       rel_residual=0.05, n_probes=8, backend=backend)


def _assert_logs_match(a, b):
    assert np.array_equal(a.cuts, b.cuts)
    np.testing.assert_allclose(a.freqs, b.freqs, rtol=1e-5)
    np.testing.assert_allclose(a.delays, b.delays, rtol=1e-4)
    np.testing.assert_allclose(a.energies, b.energies, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# Analytic/measured equivalence — the acceptance bar
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("state", STATES)
@pytest.mark.parametrize("arch", ARCHS)
def test_measured_reproduces_analytic_decisions(arch, state):
    """batched_card on an analytic-synthesized table == pure analytic."""
    cfg = get_config(arch)
    kw = dict(channel_state=state, rounds=5, seed=3, respect_memory=False)
    a = simulate_fleet(cfg, **kw)
    m = simulate_fleet(cfg, cost_source="measured",
                       latency_table=_analytic_table(arch), **kw)
    _assert_logs_match(a, m)


def test_measured_reproduces_analytic_scalar_engine():
    """Same equivalence through the scalar oracle (RoundContext + card)."""
    cfg = get_config("llama32-1b")
    kw = dict(channel_state="normal", rounds=4, seed=5, engine="scalar",
              respect_memory=False)
    a = simulate_fleet(cfg, **kw)
    m = simulate_fleet(cfg, cost_source="measured",
                       latency_table=_analytic_table("llama32-1b"), **kw)
    _assert_logs_match(a, m)


def test_analytic_table_flops_match_workload_exactly():
    """TableCompute on a from_analytic table is bit-for-bit the Workload
    accounting at every cut — not just decision-equivalent."""
    for arch in ARCHS:
        cfg = get_config(arch)
        w = Workload(cfg, BATCH, SEQ)
        tc = resolve_compute(w, "measured", _analytic_table(arch))
        for cut in range(cfg.n_layers + 1):
            assert tc.device_flops(cut) == pytest.approx(
                w.device_flops(cut), rel=1e-12)
            assert tc.server_flops(cut) == pytest.approx(
                w.server_flops(cut), rel=1e-9)
        assert tc.total_flops() == pytest.approx(w.total_flops(), rel=1e-12)


def test_measured_batched_card_end_to_end():
    """A calibrated (synthetic-fit) table runs through batched_card and
    produces sane decisions: valid cuts, clipped frequencies, finite costs."""
    cfg = get_config("llama32-1b")
    table = LatencyTable.from_fit(cfg, _synthetic_fit(), batch=BATCH,
                                  seq_len=SEQ)
    log = simulate_fleet(cfg, cost_source="measured", latency_table=table,
                         rounds=4, seed=1, respect_memory=False)
    assert ((log.cuts >= 0) & (log.cuts <= cfg.n_layers)).all()
    for m, dev in enumerate(EDGE_FLEET):
        assert (log.freqs[:, m] <= dev.f_max * (1 + 1e-6)).all()
    assert np.isfinite(log.delays).all() and (log.delays > 0).all()
    assert np.isfinite(log.energies).all()


def test_round_context_measured_vs_analytic_costs():
    """Per-cut objective sweep: measured-with-analytic-table == analytic."""
    cfg = get_config("qwen3-4b")
    w = Workload(cfg, BATCH, SEQ)
    from repro.core.channel import WirelessChannel
    ch = WirelessChannel("normal", seed=2).draw()
    base = dict(workload=w, device=EDGE_FLEET[2], server=SERVER_RTX4060TI,
                channel=ch, sim=DEFAULT_SIM)
    ctx_a = RoundContext(**base)
    ctx_m = RoundContext(cost_source="measured",
                         latency_table=_analytic_table("qwen3-4b"), **base)
    f = SERVER_RTX4060TI.f_max
    for cut in (0, cfg.n_layers // 2, cfg.n_layers):
        assert ctx_m.device_comp_delay(cut) == pytest.approx(
            ctx_a.device_comp_delay(cut), rel=1e-12)
        assert ctx_m.server_comp_delay(cut, f) == pytest.approx(
            ctx_a.server_comp_delay(cut, f), rel=1e-9)


def test_batched_context_build_measured():
    w = Workload(get_config("llama32-1b"), BATCH, SEQ)
    from repro.core.channel import draw_channel_matrix
    chans = draw_channel_matrix("normal", 2, len(EDGE_FLEET), seed=0)
    b_a = BatchedRoundContext.build(w, EDGE_FLEET, SERVER_RTX4060TI, chans,
                                    DEFAULT_SIM)
    b_m = BatchedRoundContext.build(w, EDGE_FLEET, SERVER_RTX4060TI, chans,
                                    DEFAULT_SIM, cost_source="measured",
                                    latency_table=_analytic_table(
                                        "llama32-1b"))
    np.testing.assert_allclose(np.asarray(b_m.dev_flops),
                               np.asarray(b_a.dev_flops), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(b_m.srv_flops),
                               np.asarray(b_a.srv_flops), rtol=1e-6)


# ---------------------------------------------------------------------------
# Roofline fit
# ---------------------------------------------------------------------------


def _probes_from_model(t0, inv_c, inv_b, backend="jnp"):
    shapes = [(1e6, 1e5), (1e7, 1e6), (1e8, 1e7), (1e9, 5e7),
              (5e9, 1e8), (2e10, 4e8), (1e6, 1e8), (1e7, 5e8)]
    return [ProbeResult(kernel="synthetic", backend=backend, shape=f"p{i}",
                        flops=f, hbm_bytes=by,
                        seconds=t0 + f * inv_c + by * inv_b)
            for i, (f, by) in enumerate(shapes)]


def test_fit_recovers_known_roofline():
    t0, inv_c, inv_b = 2e-4, 1e-11, 5e-12
    fit = fit_roofline(_probes_from_model(t0, inv_c, inv_b))
    assert fit.overhead_s == pytest.approx(t0, rel=1e-6)
    assert fit.inv_compute == pytest.approx(inv_c, rel=1e-6)
    assert fit.inv_bandwidth == pytest.approx(inv_b, rel=1e-6)
    assert fit.ref_throughput == pytest.approx(1.0 / inv_c, rel=1e-6)
    assert fit.rel_residual < 1e-6
    # predictions reproduce the generating model
    assert fit.predict(1e9, 1e7) == pytest.approx(
        t0 + 1e9 * inv_c + 1e7 * inv_b, rel=1e-6)


def test_fit_nnls_clips_to_nonnegative():
    """Compute-only data must not produce a negative bandwidth slope."""
    fit = fit_roofline(_probes_from_model(1e-4, 1e-11, 0.0))
    assert fit.inv_bandwidth >= 0.0
    assert fit.inv_compute > 0.0
    assert fit.overhead_s >= 0.0


def test_fit_bandwidth_bound_host_has_finite_currency():
    """When compute never binds, ref_throughput falls back to the achieved
    rate — LatencyTable construction must stay finite."""
    fit = fit_roofline(_probes_from_model(0.0, 0.0, 1e-11))
    assert fit.inv_compute == 0.0
    assert np.isfinite(fit.ref_throughput) and fit.ref_throughput > 0
    table = LatencyTable.from_fit(get_config("llama32-1b"), fit,
                                  batch=BATCH, seq_len=SEQ)
    assert np.isfinite(table.ref_throughput)


def test_fit_requires_probes():
    with pytest.raises(ValueError):
        fit_roofline([])


# ---------------------------------------------------------------------------
# LatencyTable / TableCompute validation and serialization
# ---------------------------------------------------------------------------


def test_latency_table_roundtrip():
    table = LatencyTable.from_fit(get_config("qwen3-4b"), _synthetic_fit(),
                                  batch=BATCH, seq_len=SEQ)
    again = LatencyTable.from_dict(table.to_dict())
    assert again == table
    fit = _synthetic_fit()
    assert RooflineFit.from_dict(fit.to_dict()) == fit


def test_latency_table_rejects_bad_schema_and_values():
    d = _analytic_table("llama32-1b").to_dict()
    d["schema"] = "nonsense/v9"
    with pytest.raises(ValueError):
        LatencyTable.from_dict(d)
    good = _analytic_table("llama32-1b")
    with pytest.raises(ValueError):
        LatencyTable(arch=good.arch, batch=good.batch, seq_len=good.seq_len,
                     ref_throughput=0.0, embed_s=good.embed_s,
                     layer_s=good.layer_s, head_s=good.head_s)
    with pytest.raises(ValueError):
        LatencyTable(arch=good.arch, batch=good.batch, seq_len=good.seq_len,
                     ref_throughput=1.0, embed_s=good.embed_s,
                     layer_s=(-1.0,) * good.n_layers, head_s=good.head_s)


def test_table_compute_validates_workload_match():
    w = Workload(get_config("llama32-1b"), BATCH, SEQ)
    with pytest.raises(ValueError):  # wrong architecture
        TableCompute(workload=w, table=_analytic_table("qwen3-4b"))
    with pytest.raises(ValueError):  # wrong measurement shape
        TableCompute(workload=Workload(get_config("llama32-1b"), 8, 256),
                     table=_analytic_table("llama32-1b"))
    good = _analytic_table("llama32-1b")
    with pytest.raises(ValueError):  # wrong depth
        TableCompute(workload=w, table=LatencyTable(
            arch=good.arch, batch=good.batch, seq_len=good.seq_len,
            ref_throughput=1.0, embed_s=good.embed_s,
            layer_s=good.layer_s[:-1], head_s=good.head_s))


def test_resolve_compute_errors():
    w = Workload(get_config("llama32-1b"), BATCH, SEQ)
    with pytest.raises(ValueError):
        resolve_compute(w, "measured")          # needs a table
    with pytest.raises(ValueError):
        resolve_compute(w, "vibes")             # unknown source


def test_build_latency_tables_covers_archs():
    tables = build_latency_tables(_synthetic_fit(), batch=BATCH, seq_len=SEQ,
                                  archs=ARCHS)
    assert set(tables) == set(ARCHS)
    for arch, t in tables.items():
        assert t.arch == arch
        assert t.n_layers == get_config(arch).n_layers
        assert t.source == "measured:jnp"


def test_profile_from_throughput():
    prof = profile_from_throughput("bench-host", 1.23e11)
    assert prof.delta * prof.f_max == pytest.approx(1.23e11)
