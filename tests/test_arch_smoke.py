"""Per-architecture smoke tests: reduced variant of each family, one
forward/train step + one decode step on CPU; asserts shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models import model as M
from repro.optim import adamw, constant_schedule, apply_updates


def _batch(cfg, key, b=2, s=32):
    k_tok, k_emb = jax.random.split(key)
    toks = jax.random.randint(k_tok, (b, s + 1), 0, cfg.vocab_size)
    batch = {"labels": toks[:, 1:]}
    if cfg.input_mode == "embeds":
        batch["embeds"] = jax.random.normal(k_emb, (b, s, cfg.d_model),
                                            jnp.float32) * 0.02
    else:
        batch["tokens"] = toks[:, :-1]
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batch = _batch(cfg, key)

    # forward
    loss = M.forward_loss(params["frozen"], params["lora"], batch, cfg,
                          impl="naive", remat=False)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    # one LoRA train step
    opt = adamw(constant_schedule(1e-3))
    state = opt.init(params["lora"])

    def lf(lora):
        return M.forward_loss(params["frozen"], lora, batch, cfg,
                              impl="naive", remat=False)

    loss0, grads = jax.value_and_grad(lf)(params["lora"])
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree_util.tree_leaves(grads)) ** 0.5
    assert gnorm > 0, f"{arch}: zero LoRA gradient"
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in jax.tree_util.tree_leaves(grads))
    upd, state = opt.update(grads, state, params["lora"])
    lora2 = apply_updates(params["lora"], upd)
    loss1 = lf(lora2)
    assert bool(jnp.isfinite(loss1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    k_param, k_inp = jax.random.split(key)
    params = M.init_params(k_param, cfg)
    b, max_len = 2, 16
    cache = M.init_cache(cfg, b, max_len)
    if cfg.input_mode == "embeds":
        inp = jax.random.normal(k_inp, (b, 1, cfg.d_model), jnp.float32)
    else:
        inp = jax.random.randint(k_inp, (b, 1), 0, cfg.vocab_size)
    logits, cache2 = M.decode_step(params["frozen"], params["lora"], cache,
                                   inp, jnp.int32(0), cfg)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-370m", "hymba-1.5b",
                                  "granite-moe-3b-a800m", "musicgen-large"])
def test_decode_matches_forward(arch):
    """Step-by-step decode must reproduce the full-sequence forward."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    k_param, k_inp = jax.random.split(key)
    params = M.init_params(k_param, cfg)
    b, s = 2, 12
    if cfg.input_mode == "embeds":
        inputs = jax.random.normal(k_inp, (b, s, cfg.d_model),
                                   jnp.float32) * 0.1
        step_in = lambda t: inputs[:, t:t + 1]
    else:
        inputs = jax.random.randint(k_inp, (b, s), 0, cfg.vocab_size)
        step_in = lambda t: inputs[:, t:t + 1]
    x, _ = M.forward_hidden(params["frozen"], params["lora"], inputs, cfg,
                            impl="naive", remat=False)
    full = M.logits_from_hidden(params["frozen"], x, cfg)
    cache = M.init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        lg, cache = M.decode_step(params["frozen"], params["lora"], cache,
                                  step_in(t), jnp.int32(t), cfg)
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    assert float(jnp.max(jnp.abs(dec - full))) < 5e-4


def test_sliding_window_ring_buffer_decode():
    """SWA ring-buffer cache must equal full-cache attention within window."""
    from dataclasses import replace
    cfg = replace(get_config("qwen3-0.6b").reduced(), sliding_window=8)
    key = jax.random.PRNGKey(3)
    params = M.init_params(key, cfg)
    b, s = 1, 20
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    x, _ = M.forward_hidden(params["frozen"], params["lora"], toks, cfg,
                            impl="naive", remat=False)
    full = M.logits_from_hidden(params["frozen"], x, cfg)
    cache = M.init_cache(cfg, b, s)   # ring: 8 slots only
    assert cache["kv"]["k"].shape[2] == 8
    outs = []
    for t in range(s):
        lg, cache = M.decode_step(params["frozen"], params["lora"], cache,
                                  toks[:, t:t + 1], jnp.int32(t), cfg)
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    assert float(jnp.max(jnp.abs(dec - full))) < 5e-4


def test_int8_kv_cache_decode():
    """int8 KV cache (phi-compression applied to serving) stays within
    quantization tolerance of the fp cache decode."""
    from dataclasses import replace
    cfg = replace(get_config("qwen3-0.6b").reduced(), kv_cache_dtype="int8")
    key = jax.random.PRNGKey(4)
    params = M.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    x, _ = M.forward_hidden(params["frozen"], params["lora"], toks, cfg,
                            impl="naive", remat=False)
    full = M.logits_from_hidden(params["frozen"], x, cfg)
    cache = M.init_cache(cfg, 2, 16)
    assert cache["kv"]["k"].dtype == jnp.int8
    assert "k_scale" in cache["kv"]
    outs = []
    for t in range(16):
        lg, cache = M.decode_step(params["frozen"], params["lora"], cache,
                                  toks[:, t:t + 1], jnp.int32(t), cfg)
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    assert float(jnp.max(jnp.abs(dec - full))) < 0.15
