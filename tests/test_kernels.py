"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the exact TPU kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

ATOL = {jnp.float32: 2e-4, jnp.bfloat16: 2e-1}


# ---------------------------------------------------------------------------
# lora_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n,r", [
    (64, 64, 64, 4), (100, 96, 72, 8), (256, 128, 512, 16),
    (33, 70, 65, 2),  # awkward non-multiples exercise padding
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_matmul_sweep(m, k, n, r, dtype):
    kk = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(kk[0], (m, k), jnp.float32).astype(dtype)
    w = jax.random.normal(kk[1], (k, n), jnp.float32).astype(dtype)
    a = jax.random.normal(kk[2], (k, r), jnp.float32).astype(dtype)
    b = jax.random.normal(kk[3], (r, n), jnp.float32).astype(dtype)
    got = ops.lora_matmul(x, w, a, b, 0.5, bm=32, bn=64, bk=32)
    want = ref.lora_matmul_ref(x, w, a, b, 0.5)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype] * np.abs(np.asarray(want, np.float32)).max(),
        rtol=0)


def test_lora_matmul_batched_leading_dims():
    kk = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(kk[0], (2, 17, 64))
    w = jax.random.normal(kk[1], (64, 48))
    a = jax.random.normal(kk[2], (64, 4))
    b = jax.random.normal(kk[3], (4, 48))
    got = ops.lora_matmul(x, w, a, b, 1.0, bm=16, bn=16, bk=16)
    want = ref.lora_matmul_ref(x.reshape(-1, 64), w, a, b, 1.0).reshape(2, 17, 48)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


@pytest.mark.parametrize("g,m,k,n,r,e", [
    (3, 1, 64, 48, 4, 2),     # decode shape: one token per request
    (4, 8, 128, 128, 8, 4),
    (2, 5, 100, 72, 4, 5),    # awkward non-multiples exercise padding
    (6, 1, 256, 96, 16, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_matmul_grouped_sweep(g, m, k, n, r, e, dtype):
    kk = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(kk[0], (g, m, k), jnp.float32).astype(dtype)
    w = jax.random.normal(kk[1], (k, n), jnp.float32).astype(dtype)
    a = jax.random.normal(kk[2], (e, k, r), jnp.float32).astype(dtype)
    b = jax.random.normal(kk[3], (e, r, n), jnp.float32).astype(dtype)
    ids = jax.random.randint(kk[4], (g,), 0, e)
    got = ops.lora_matmul_grouped(x, w, a, b, ids, 0.5, bn=64, bk=32)
    want = ref.lora_matmul_grouped_ref(x, w, a, b, ids, 0.5)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype] * np.abs(np.asarray(want, np.float32)).max(),
        rtol=0)


def test_lora_matmul_grouped_matches_single_adapter_loop():
    """The multi-tenant kernel must equal a per-request lora_matmul loop."""
    kk = jax.random.split(jax.random.PRNGKey(3), 5)
    g, m, k, n, r, e = 5, 4, 96, 80, 8, 3
    x = jax.random.normal(kk[0], (g, m, k))
    w = jax.random.normal(kk[1], (k, n))
    a = jax.random.normal(kk[2], (e, k, r))
    b = jax.random.normal(kk[3], (e, r, n))
    ids = jax.random.randint(kk[4], (g,), 0, e)
    got = ops.lora_matmul_grouped(x, w, a, b, ids, 0.7, bn=32, bk=32)
    want = jnp.stack([ops.lora_matmul(x[gi], w, a[aid], b[aid], 0.7,
                                      bm=16, bn=32, bk=32)
                      for gi, aid in enumerate(np.asarray(ids))])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_lora_matmul_grouped_2d_rows():
    """(G, K) input (one token per request, no M axis) squeezes through."""
    kk = jax.random.split(jax.random.PRNGKey(4), 5)
    g, k, n, r, e = 4, 64, 48, 4, 2
    x = jax.random.normal(kk[0], (g, k))
    w = jax.random.normal(kk[1], (k, n))
    a = jax.random.normal(kk[2], (e, k, r))
    b = jax.random.normal(kk[3], (e, r, n))
    ids = jnp.asarray([0, 1, 1, 0])
    got = ops.lora_matmul_grouped(x, w, a, b, ids, 1.0, bn=16, bk=16)
    want = ref.lora_matmul_grouped_ref(x[:, None, :], w, a, b, ids, 1.0)[:, 0]
    assert got.shape == (g, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sq,skv,hq,hkv,d", [
    (128, 128, 4, 4, 32),    # MHA
    (128, 128, 8, 2, 32),    # GQA
    (200, 200, 4, 2, 64),    # non-multiple seq
    (96, 96, 25, 5, 16),     # hymba-style head count
])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_sweep(sq, skv, hq, hkv, d, window):
    kk = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(kk[0], (2, sq, hq, d))
    k = jax.random.normal(kk[1], (2, skv, hkv, d))
    v = jax.random.normal(kk[2], (2, skv, hkv, d))
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    from repro.models.attention import naive_attention
    pos = jnp.broadcast_to(jnp.arange(sq), (2, sq))
    want = naive_attention(q, k, v, causal=True, window=window,
                           q_positions=pos, k_positions=pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    kk = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(kk[0], (1, 128, 4, 32)).astype(dtype)
    k = jax.random.normal(kk[1], (1, 128, 4, 32)).astype(dtype)
    v = jax.random.normal(kk[2], (1, 128, 4, 32)).astype(dtype)
    got = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    want = ref.flash_attention_ref(
        q.reshape(4, 128, 32).transpose(0, 1, 2),
        k.reshape(4, 128, 32), v.reshape(4, 128, 32))
    # reshape mismatch: use the model-side oracle instead
    from repro.models.attention import naive_attention
    pos = jnp.broadcast_to(jnp.arange(128), (1, 128))
    want = naive_attention(q, k, v, causal=True, window=0,
                           q_positions=pos, k_positions=pos)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("l,nh,hp,ns,chunk", [
    (64, 2, 16, 16, 16), (100, 3, 16, 24, 32), (256, 4, 32, 64, 64),
])
def test_ssd_scan_sweep(l, nh, hp, ns, chunk):
    kk = jax.random.split(jax.random.PRNGKey(4), 4)
    xt = jax.random.normal(kk[0], (2, l, nh, hp)) * 0.2
    a = -jnp.abs(jax.random.normal(kk[1], (2, l, nh))) * 0.1
    B = jax.random.normal(kk[2], (2, l, ns)) * 0.3
    C = jax.random.normal(kk[3], (2, l, ns)) * 0.3
    y1, h1 = ops.ssd_scan(xt, a, B, C, chunk)
    from repro.models.mamba import ssd_chunked
    y2, h2 = ssd_chunked(xt, a, B, C, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


def test_ssd_intra_chunk_against_ref():
    from repro.kernels.ssd_scan import ssd_intra_chunk
    kk = jax.random.split(jax.random.PRNGKey(5), 4)
    b, nc, cl, nh, hp, ns = 2, 3, 16, 2, 8, 12
    xt = jax.random.normal(kk[0], (b, nc, cl, nh, hp)) * 0.2
    a = -jnp.abs(jax.random.normal(kk[1], (b, nc, cl, nh))) * 0.1
    B = jax.random.normal(kk[2], (b, nc, cl, ns)) * 0.3
    C = jax.random.normal(kk[3], (b, nc, cl, ns)) * 0.3
    y1, st1, dec1 = ssd_intra_chunk(xt, a, B, C, interpret=True)
    y2, st2, dec2 = ref.ssd_intra_chunk_ref(xt, a, B, C)
    # kernel emits states as (ns, hp); ref as (nh, ns, hp) per chunk
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    st1t = np.asarray(st1)                       # (b, nc, nh, ns, hp)
    st2t = np.asarray(st2)                       # (b, nc, nh, ns, hp)
    np.testing.assert_allclose(st1t, st2t, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dec1[..., 0]),
                               np.asarray(dec2[..., 0]), atol=1e-4)


# ---------------------------------------------------------------------------
# flash_decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s,hq,hkv,d,t,window", [
    (100, 8, 4, 32, 0, 0),      # first token
    (100, 8, 4, 32, 42, 0),     # mid-cache
    (100, 8, 4, 32, 99, 0),     # full cache
    (100, 8, 4, 32, 60, 32),    # sliding window over linear cache
    (64, 4, 4, 16, 150, 64),    # ring buffer (window == slots, t > slots)
])
def test_flash_decode_sweep(s, hq, hkv, d, t, window):
    from repro.models.attention import naive_attention
    kk = jax.random.split(jax.random.PRNGKey(7), 3)
    b = 2
    q = jax.random.normal(kk[0], (b, 1, hq, d))
    k = jax.random.normal(kk[1], (b, s, hkv, d))
    v = jax.random.normal(kk[2], (b, s, hkv, d))
    got = ops.flash_decode(q, k, v, jnp.int32(t), window=window, block_k=32)
    pos = jnp.full((b, 1), t, jnp.int32)
    j = jnp.arange(s, dtype=jnp.int32)
    if window and window <= s and t >= s:
        abs_pos = t - ((t - j) % s)
        abs_pos = jnp.where(abs_pos >= 0, abs_pos, 2**30)
        kpos = jnp.broadcast_to(abs_pos, (b, s))
    else:
        kpos = jnp.broadcast_to(j, (b, s))
    want = naive_attention(q, k, v, causal=True, window=window,
                           q_positions=pos, k_positions=kpos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_decode_bf16(dtype):
    kk = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(kk[0], (1, 1, 4, 32)).astype(dtype)
    k = jax.random.normal(kk[1], (1, 96, 2, 32)).astype(dtype)
    v = jax.random.normal(kk[2], (1, 96, 2, 32)).astype(dtype)
    got = ops.flash_decode(q, k, v, jnp.int32(95), block_k=32)
    from repro.models.attention import naive_attention
    pos = jnp.full((1, 1), 95, jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(96), (1, 96))
    want = naive_attention(q, k, v, causal=True, window=0,
                           q_positions=pos, k_positions=kpos)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)
