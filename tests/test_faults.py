"""Churn tolerance: fault model determinism, the zero-fault identity, the
deadline partial-aggregation seam, and the retry/circuit-breaker/quorum
protocol hardening — all deterministic (injected faults, injected clocks).
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import card as C
from repro.core.channel import WirelessChannel
from repro.core.faults import (CircuitBreaker, DeadlinePolicy, ExchangeFailed,
                               FaultInjector, FaultModel, LinkTimeout,
                               RetryPolicy, retry_call)
from repro.core.hardware import (EDGE_FLEET, SERVER_RTX4060TI, SimParams,
                                 make_heterogeneous_fleet)
from repro.core.protocol import SplitFineTuner
from repro.core.scheduler import parallel_round_stats, simulate_fleet
from repro.data import make_fleet_datasets
from repro.models import model as M
from repro.optim import adamw, constant_schedule

HEAVY = FaultModel(dropout_prob=0.2, straggler_prob=0.3, outage_prob=0.1,
                   leave_prob=0.05)


# ---------------------------------------------------------------------------
# FaultModel / FaultRealization
# ---------------------------------------------------------------------------


def test_realization_deterministic_and_prefix_stable():
    a = HEAVY.realize(12, 6, seed=3)
    b = HEAVY.realize(12, 6, seed=3)
    for k in ("active", "dropout", "compute_slowdown", "link_slowdown",
              "outage"):
        assert np.array_equal(getattr(a, k), getattr(b, k)), k
    # per-device streams: adding devices never perturbs existing ones
    wide = HEAVY.realize(12, 9, seed=3)
    assert np.array_equal(wide.dropout[:, :6], a.dropout)
    assert np.array_equal(wide.compute_slowdown[:, :6], a.compute_slowdown)
    # a different seed actually changes the draws
    assert not np.array_equal(HEAVY.realize(12, 6, seed=4).dropout, a.dropout)


def test_zero_probability_model_is_identity():
    r = FaultModel().realize(8, 5, seed=0)
    assert r.active.all() and not r.dropout.any() and not r.outage.any()
    assert (r.compute_slowdown == 1.0).all()
    assert (r.link_slowdown == 1.0).all()
    assert r.participating.all()


def test_model_validation():
    with pytest.raises(ValueError):
        FaultModel(dropout_prob=1.5)
    with pytest.raises(ValueError):
        FaultModel(slowdown_min=0.5)
    with pytest.raises(ValueError):
        DeadlinePolicy(quantile=0.0)


def test_membership_markov_chain_rejoins():
    fm = FaultModel(leave_prob=0.3, rejoin_prob=0.7)
    r = fm.realize(200, 4, seed=1)
    active = r.active
    # devices leave AND come back (two-state chain mixes)
    assert 0.0 < active.mean() < 1.0
    left = (~active[1:] & active[:-1]).any()
    rejoined = (active[1:] & ~active[:-1]).any()
    assert left and rejoined


# ---------------------------------------------------------------------------
# simulate_fleet: the zero-fault identity and the fault overlay
# ---------------------------------------------------------------------------


def test_fault_free_log_bit_identical():
    """fault_model=None and the zero-probability model produce the *same
    bits* as today's simulation — the hardest acceptance criterion."""
    cfg = get_config("llama32-1b")
    base = simulate_fleet(cfg, rounds=6, seed=7)
    degenerate = simulate_fleet(cfg, rounds=6, seed=7,
                                fault_model=FaultModel(),
                                deadline=DeadlinePolicy(quantile=1.0))
    assert np.array_equal(base.delays, degenerate.delays)
    assert np.array_equal(base.energies, degenerate.energies)
    assert degenerate.participation.all()
    assert degenerate.survivor_fraction() == 1.0


def test_engines_decision_identical_under_faults():
    cfg = get_config("llama32-1b")
    kw = dict(rounds=5, seed=11, fault_model=HEAVY,
              deadline=DeadlinePolicy(quantile=0.9, objective_deadline_s=5.0))
    a = simulate_fleet(cfg, engine="scalar", **kw)
    b = simulate_fleet(cfg, engine="vectorized", **kw)
    assert np.array_equal(a.cuts, b.cuts)
    np.testing.assert_allclose(a.freqs, b.freqs, rtol=1e-5)
    assert np.array_equal(a.participation, b.participation)
    np.testing.assert_allclose(a.delays, b.delays, rtol=1e-4)


def test_deadline_objective_changes_decisions_toward_deadline():
    """A tight deadline pushes CARD to faster configs: any (cut, f) meeting
    it beats any that misses, so nominal delays shrink toward the deadline."""
    cfg = get_config("llama32-1b")
    base = simulate_fleet(cfg, rounds=8, seed=2)
    deadline_s = float(np.quantile(base.delays, 0.25))
    tight = simulate_fleet(
        cfg, rounds=8, seed=2,
        deadline=DeadlinePolicy(quantile=1.0,
                                objective_deadline_s=deadline_s,
                                objective_penalty=100.0))
    changed = (tight.cuts != base.cuts) | ~np.isclose(tight.freqs, base.freqs)
    assert changed.any()
    assert tight.mean_delay() < base.mean_delay()
    # the changed decisions never got *slower*
    assert (tight.delays[changed] <= base.delays[changed] + 1e-9).all()


def test_straggler_overlay_and_partial_aggregation():
    cfg = get_config("llama32-1b")
    fm = FaultModel(straggler_prob=0.4, slowdown_min=3.0, slowdown_max=5.0)
    log = simulate_fleet(cfg, rounds=10, seed=5, fault_model=fm,
                         deadline=DeadlinePolicy(quantile=0.8))
    # some devices were late and dropped; survivors' stats stay finite
    assert 0.0 < log.survivor_fraction() < 1.0
    assert np.isfinite(log.mean_delay()) and np.isfinite(log.mean_energy())
    assert np.isnan(log.delays[~log.participation]).all()
    # the server closed every round no later than its worst survivor + stall
    assert np.isfinite(log.round_close_s).all()
    stats = parallel_round_stats(log)
    for v in stats.values():
        assert np.isfinite(v), stats


def test_masked_reductions_ignore_nan():
    cfg = get_config("llama32-1b")
    log = simulate_fleet(cfg, rounds=4, seed=1)
    clean_delay = log.mean_delay()
    log.delays[0, 0] = np.nan
    log.energies[0, 0] = np.nan
    assert np.isfinite(log.mean_delay())
    assert log.mean_delay() != clean_delay
    assert np.isfinite(parallel_round_stats(log)["parallel_exact_s"])


def test_thousand_device_churn_sweep_completes():
    """Acceptance: 1000 heterogeneous devices at 20% dropout + stragglers
    complete a sweep through deadline-based partial aggregation."""
    cfg = get_config("llama32-1b")
    fleet = make_heterogeneous_fleet(1000, seed=0)
    fm = FaultModel(dropout_prob=0.2, straggler_prob=0.2)
    log = simulate_fleet(cfg, rounds=3, seed=0, devices=fleet,
                         fault_model=fm, deadline=DeadlinePolicy(quantile=0.9))
    assert log.delays.shape == (3, 1000)
    # ~20% dropout plus the late tail; well over half the fleet commits
    assert 0.5 < log.survivor_fraction() < 0.9
    assert np.isfinite(log.mean_delay())
    assert np.isfinite(log.round_close_s).all()


# ---------------------------------------------------------------------------
# DeadlineSpec objective (scalar vs batched miss probability)
# ---------------------------------------------------------------------------


def test_miss_probability_cases():
    spec = C.DeadlineSpec(deadline_s=2.0, p_dropout=0.1, p_straggler=0.3,
                          slowdown=2.0)
    on_time = float(C.miss_probability(np.float64(0.5), spec))
    risky = float(C.miss_probability(np.float64(1.5), spec))   # 1.5*2 > 2
    late = float(C.miss_probability(np.float64(3.0), spec))
    assert on_time == pytest.approx(0.1)                # dropout only
    assert risky == pytest.approx(0.1 + 0.9 * 0.3)      # + straggler tail
    assert late == pytest.approx(1.0)
    for d in (0.5, 1.5, 3.0):
        assert float(C.miss_probability(np.float64(d), spec)) == \
            pytest.approx(C._miss_probability_scalar(d, spec))


# ---------------------------------------------------------------------------
# retry_call / RetryPolicy
# ---------------------------------------------------------------------------


def _flaky(fail_times, exc=LinkTimeout):
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        if calls["n"] <= fail_times:
            raise exc(f"boom {calls['n']}")
        return "ok"
    return fn, calls


def test_retry_succeeds_after_transient_failures():
    fn, calls = _flaky(2)
    pol = RetryPolicy(max_attempts=4, base_backoff_s=0.1, max_backoff_s=1.0)
    result, attempts, backoff_s = retry_call(fn, pol)
    assert result == "ok" and attempts == 3 and calls["n"] == 3
    assert backoff_s == pytest.approx(0.1 + 0.2)    # exponential, uncapped


def test_retry_backoff_caps():
    fn, _ = _flaky(5)
    pol = RetryPolicy(max_attempts=6, base_backoff_s=0.1, max_backoff_s=0.25)
    result, attempts, backoff_s = retry_call(fn, pol)
    assert result == "ok" and attempts == 6
    assert backoff_s == pytest.approx(0.1 + 0.2 + 0.25 + 0.25 + 0.25)


def test_retry_exhaustion_raises_with_accounting():
    fn, calls = _flaky(99)
    with pytest.raises(ExchangeFailed) as ei:
        retry_call(fn, RetryPolicy(max_attempts=3, base_backoff_s=0.05))
    assert ei.value.attempts == 3 and calls["n"] == 3
    assert ei.value.backoff_s == pytest.approx(0.05 + 0.1)


def test_retry_timeout_budget_with_fake_clock():
    fn, calls = _flaky(99)
    t = {"now": 0.0}

    def clock():
        t["now"] += 10.0        # each attempt "takes" 10 s
        return t["now"]

    pol = RetryPolicy(max_attempts=10, base_backoff_s=1.0, timeout_s=25.0)
    with pytest.raises(ExchangeFailed) as ei:
        retry_call(fn, pol, clock=clock)
    assert "timeout budget" in str(ei.value)
    assert calls["n"] < 10                     # budget cut the retries short


def test_retry_does_not_catch_unlisted_exceptions():
    def fn():
        raise ValueError("not retryable")
    with pytest.raises(ValueError):
        retry_call(fn, RetryPolicy())


def test_retry_sleep_is_injected():
    fn, _ = _flaky(1)
    pauses = []
    retry_call(fn, RetryPolicy(max_attempts=2, base_backoff_s=0.5),
               sleep=pauses.append)
    assert pauses == [0.5]


# ---------------------------------------------------------------------------
# CircuitBreaker
# ---------------------------------------------------------------------------


def test_breaker_opens_after_threshold_and_half_opens():
    br = CircuitBreaker(failure_threshold=2, cooldown_rounds=3)
    assert br.allow(0, 0)
    br.record_failure(0, 0)
    assert br.allow(0, 1)                       # one failure: still closed
    br.record_failure(0, 1)                     # second consecutive: open
    assert not br.allow(0, 2) and br.evicted(2) == [0]
    assert not br.allow(0, 4)                   # cool-down covers 2..4
    assert br.allow(0, 5)                       # half-open probe
    br.record_failure(0, 5)                     # probe fails: re-open at once
    assert not br.allow(0, 6)
    assert br.allow(0, 9)
    br.record_success(0)                        # probe succeeds: fully closed
    br.record_failure(0, 10)
    assert br.allow(0, 11)                      # counter was reset


def test_breaker_is_per_device():
    br = CircuitBreaker(failure_threshold=1, cooldown_rounds=2)
    br.record_failure(3, 0)
    assert not br.allow(3, 1) and br.allow(4, 1)


# ---------------------------------------------------------------------------
# FaultInjector semantics
# ---------------------------------------------------------------------------


def test_injector_outage_recovers_on_retry():
    fm = FaultModel(outage_prob=1.0)
    inj = FaultInjector(fm.realize(2, 1, seed=0))
    with pytest.raises(LinkTimeout):
        inj.check(0, 0, attempt=1)
    inj.check(0, 0, attempt=2)                  # retry succeeds


def test_injector_dropout_never_recovers():
    fm = FaultModel(dropout_prob=1.0)
    inj = FaultInjector(fm.realize(2, 1, seed=0))
    for attempt in (1, 2, 5):
        with pytest.raises(LinkTimeout):
            inj.check(1, 0, attempt=attempt)
    assert inj.is_member(1, 0)                  # member, just unreachable


# ---------------------------------------------------------------------------
# SplitFineTuner under injected churn (real JAX training, tiny config)
# ---------------------------------------------------------------------------


def _make_tuner(n_devices, n_rounds, fm, *, quorum=0.5, seed=0,
                retry=None, breaker=None):
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    datasets = make_fleet_datasets(cfg, n_devices, vocab=cfg.vocab_size,
                                   seed=1)
    sim = SimParams(local_epochs=1, mini_batch=4, seq_len=32)
    inj = FaultInjector(fm.realize(n_rounds, n_devices, seed=seed))
    return SplitFineTuner(
        cfg, params["frozen"], params["lora"],
        adamw(constant_schedule(3e-3)),
        devices=list(EDGE_FLEET[:n_devices]), server=SERVER_RTX4060TI,
        channels=[WirelessChannel("normal", seed=i)
                  for i in range(n_devices)],
        datasets=datasets, sim=sim, policy="card", fault_injector=inj,
        retry_policy=retry or RetryPolicy(max_attempts=2,
                                          base_backoff_s=0.01),
        breaker=breaker or CircuitBreaker(), quorum=quorum)


def test_protocol_outages_retried_transparently():
    ft = _make_tuner(2, 3, FaultModel(outage_prob=1.0))
    res = ft.run(3)
    ok = [l for l in res.logs if l.status == "ok"]
    assert len(ok) == 6                         # every slot survived
    assert all(l.attempts == 2 for l in ok)     # via one retry each
    assert all(l.backoff_s > 0 for l in ok)
    assert res.rounds_committed() == 3


def test_protocol_dropout_breaker_evicts_repeat_offender():
    # device 1 hard-drops every round; threshold 2 evicts it after 2 rounds
    fm = FaultModel()
    real = fm.realize(6, 2, seed=0)
    real.dropout[:, 1] = True
    ft = _make_tuner(2, 6, fm, quorum=0.4,
                     breaker=CircuitBreaker(failure_threshold=2,
                                            cooldown_rounds=10))
    ft.fault_injector = FaultInjector(real)
    res = ft.run(6)
    by_status = {}
    for l in res.logs:
        if l.device == ft.devices[1].name:
            by_status.setdefault(l.status, 0)
            by_status[l.status] += 1
    assert by_status.get("dropped") == 2        # two strikes
    assert by_status.get("evicted") == 4        # then the breaker opens
    # healthy device 0 carries every round to quorum (1 of <=2 attempted)
    assert res.rounds_committed() == 6


def test_protocol_below_quorum_rolls_back():
    fm = FaultModel(dropout_prob=1.0)           # nobody ever survives
    ft = _make_tuner(2, 2, fm, quorum=0.5,
                     breaker=CircuitBreaker(failure_threshold=99,
                                            cooldown_rounds=1))
    lora_before = jax.device_get(ft.lora)
    res = ft.run(2)
    assert res.rounds_committed() == 0
    assert all(not s.committed for s in res.round_summaries)
    # adapters rolled back to their initial state
    after = jax.device_get(res.lora)
    for a, b in zip(jax.tree_util.tree_leaves(lora_before),
                    jax.tree_util.tree_leaves(after), strict=True):
        np.testing.assert_array_equal(a, b)
    assert np.isnan(res.mean_delay())           # NaN-safe, not a crash
    assert res.losses() == []


def test_protocol_absent_members_are_skipped_not_failed():
    fm = FaultModel(initial_absent_prob=1.0, rejoin_prob=0.0)
    ft = _make_tuner(2, 2, fm)
    res = ft.run(2)
    assert all(l.status == "absent" for l in res.logs)
    assert all(s.attempted == 0 for s in res.round_summaries)
    assert not any(s.committed for s in res.round_summaries)
