"""Config registry: every assigned arch resolves, with the exact shapes."""
import pytest

from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, all_configs,
                                get_config, long_context_variant)

EXPECTED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
    "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
    "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
    "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
    "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
    "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
}


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_assigned_config_exact(arch):
    c = get_config(arch)
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == EXPECTED[arch]
    assert c.source, "every config must cite its source"


def test_moe_fields():
    g = get_config("granite-moe-3b-a800m")
    assert (g.n_experts, g.top_k) == (40, 8)
    k = get_config("kimi-k2-1t-a32b")
    assert (k.n_experts, k.top_k) == (384, 8)
    assert abs(k.total_params() - 1.04e12) / 1.04e12 < 0.05  # ~1T
    assert abs(k.active_params() - 33e9) / 33e9 < 0.10       # ~32B active


def test_ssm_fields():
    m = get_config("mamba2-370m")
    assert m.ssm_state == 128 and m.is_attention_free
    h = get_config("hymba-1.5b")
    assert h.ssm_state == 16 and h.family == "hybrid"


def test_reduced_variants_are_small():
    for arch, cfg in all_configs().items():
        r = cfg.reduced()
        assert r.n_layers <= 2 and r.d_model <= 512
        assert r.n_experts <= 4
        assert r.family == cfg.family, arch


def test_long_context_variant():
    # attention archs get a sliding window; ssm runs natively
    d = long_context_variant(get_config("qwen2-7b"))
    assert d.sliding_window == 8192
    m = long_context_variant(get_config("mamba2-370m"))
    assert m.sliding_window == 0
    h = long_context_variant(get_config("hymba-1.5b"))
    assert h.sliding_window == 1024  # keeps its own (smaller) window


def test_input_shapes():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert len(ARCH_IDS) == 11  # 10 assigned + the paper's llama32-1b
