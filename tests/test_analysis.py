"""Roofline/analysis units + dry-run record invariants from the matrix."""
import json
import os

import pytest

from repro.launch.analysis import (Roofline, _shape_bytes, model_flops,
                                   parse_collectives)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.jsonl")


def test_shape_bytes_parser():
    assert _shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert _shape_bytes("f32[2,3,4]") == 96
    assert _shape_bytes("(bf16[4], f32[4])") == 8 + 16
    assert _shape_bytes("pred[16]") == 16
    assert _shape_bytes("u32[]") == 4  # scalar


def test_parse_collectives_ignores_non_collectives():
    stats = parse_collectives("""
      %d = f32[8,8]{1,0} dot(%a, %b)
      %c = f32[8]{0} add(%x, %y)
    """)
    assert stats.total_bytes == 0 and not stats.counts


def test_parse_collectives_async_start_ops():
    stats = parse_collectives("""
      %ag = bf16[64,64]{1,0} all-gather-start(%x), dimensions={0}
      %cp = u8[16]{0} collective-permute-start(%y)
    """)
    assert stats.counts == {"all-gather": 1, "collective-permute": 1}


def test_model_flops():
    from repro.configs.base import get_config
    cfg = get_config("qwen2-7b")
    t = 1000
    assert model_flops(cfg, t, "train") == pytest.approx(
        6 * cfg.total_params() * t)
    moe = get_config("kimi-k2-1t-a32b")
    assert model_flops(moe, t, "inference") == pytest.approx(
        2 * moe.active_params() * t)


def test_roofline_dominant_classification():
    r = Roofline(flops=1e15, hbm_bytes=1e9, collective_bytes=1e9, chips=256)
    assert r.dominant == "compute"
    assert r.bound_s == r.compute_s


@pytest.mark.skipif(not os.path.exists(RESULTS),
                    reason="dry-run matrix not generated yet")
def test_dryrun_matrix_complete_and_consistent():
    """Every (10 arch x 4 shape x 2 mesh) combo present and OK; terms
    positive; decode steps lower serve_step (tokens == batch)."""
    recs = {}
    with open(RESULTS) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    from repro.configs.base import INPUT_SHAPES
    from repro.launch.dryrun import DRYRUN_ARCHS
    missing = []
    for a in DRYRUN_ARCHS:
        for s in INPUT_SHAPES:
            for m in ("16x16", "2x16x16"):
                r = recs.get((a, s, m))
                if r is None:
                    missing.append((a, s, m))
                    continue
                if not r.get("ok"):
                    missing.append((a, s, m, r.get("error", "")[:80]))
                    continue
                roof = r["roofline"]
                assert roof["flops"] > 0 and roof["hbm_bytes"] > 0
                assert roof["dominant"] in ("compute", "memory", "collective")
                if INPUT_SHAPES[s].kind == "decode":
                    assert r["tokens"] == INPUT_SHAPES[s].global_batch
    assert not missing, f"incomplete matrix: {missing[:5]}"
