import os
import sys

# Property tests want the real hypothesis (CI installs it via `.[test]`);
# hermetic environments fall back to the deterministic stub in _stubs/.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_stubs"))

# CPU-only test environment; smoke tests see 1 device (the dry-run script
# sets its own 512-device flag and is exercised as a subprocess).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
