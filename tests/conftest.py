import os

# CPU-only test environment; smoke tests see 1 device (the dry-run script
# sets its own 512-device flag and is exercised as a subprocess).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
