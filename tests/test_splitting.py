"""Split execution (Sec. II-B stages 3-4): the SL computation itself."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.splitting import (SplitExecutor, channel_compress,
                                  dequantize_int8, device_forward, merge_lora,
                                  quantize_int8, split_grads, split_lora)
from repro.models import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama32-1b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(5)
    tokens = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(6), (4, 32), 0,
                                cfg.vocab_size)
    return cfg, params, tokens, labels


@pytest.mark.parametrize("cut_frac", [0.0, 0.5, 1.0])
def test_split_grads_match_full_model(setup, cut_frac):
    """Split BP through the channel == end-to-end LoRA grads (phi off)."""
    cfg, params, tokens, labels = setup
    cut = int(cut_frac * cfg.n_layers)

    def loss_fn(lora):
        return M.forward_loss(params["frozen"], lora,
                              {"tokens": tokens, "labels": labels}, cfg,
                              impl="naive", remat=False)

    ref_loss, ref_grads = jax.value_and_grad(loss_fn)(params["lora"])
    ld, ls = split_lora(params["lora"], cut)
    loss, gd, gs = split_grads(params["frozen"], ld, ls, tokens, labels,
                               cfg=cfg, cut=cut, compress=False)
    assert float(loss) == pytest.approx(float(ref_loss), rel=1e-5)
    merged = merge_lora(gd, gs)
    for a, b in zip(jax.tree_util.tree_leaves(merged),
                    jax.tree_util.tree_leaves(ref_grads), strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_split_merge_roundtrip(setup):
    cfg, params, *_ = setup
    for cut in (0, 1, cfg.n_layers):
        d, s = split_lora(params["lora"], cut)
        m = merge_lora(d, s)
        for a, b in zip(jax.tree_util.tree_leaves(m),
                        jax.tree_util.tree_leaves(params["lora"]),
                        strict=True):
            assert a.shape == b.shape
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int8_channel_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 3.0
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    xq = dequantize_int8(q, s, x.dtype)
    # max error bounded by one quantization step per row
    step = np.asarray(s).squeeze()
    err = np.abs(np.asarray(xq - x))
    assert (err <= step[:, None] * 0.5 + 1e-6).all()


def test_channel_compress_straight_through_gradient():
    """d/dx of the quantized channel must be identity (STE)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (16,))
    g = jax.grad(lambda v: jnp.sum(channel_compress(v, True) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0, rtol=1e-6)


def test_compression_changes_forward_but_not_much(setup):
    cfg, params, tokens, labels = setup
    ld, ls = split_lora(params["lora"], 1)
    loss_c, *_ = split_grads(params["frozen"], ld, ls, tokens, labels,
                             cfg=cfg, cut=1, compress=True)
    loss_n, *_ = split_grads(params["frozen"], ld, ls, tokens, labels,
                             cfg=cfg, cut=1, compress=False)
    assert float(loss_c) != float(loss_n)          # quantization is real
    assert abs(float(loss_c) - float(loss_n)) < 0.1  # but small


def test_smashed_data_shape(setup):
    """Eq. 2: smashed data is (B, S, d) at every cut."""
    cfg, params, tokens, _ = setup
    for cut in (0, 1, 2):
        sm = device_forward(params["frozen"],
                            split_lora(params["lora"], cut)[0],
                            tokens, cfg, cut)
        assert sm.shape == (4, 32, cfg.d_model)


def test_executor_caches_programs(setup):
    cfg, params, tokens, labels = setup
    ex = SplitExecutor(cfg, compress=True)
    batch = {"tokens": tokens, "labels": labels}
    l1, g1 = ex.step(params["frozen"], params["lora"], batch, 1)
    l2, g2 = ex.step(params["frozen"], params["lora"], batch, 1)
    assert float(l1) == pytest.approx(float(l2))
    assert jax.tree_util.tree_structure(g1) == \
        jax.tree_util.tree_structure(params["lora"])
