"""Unit tests for splint (tools/splint): one positive and one negative
case per detector, plus pragma/baseline/report plumbing and the
unit-suffix payload-key validation used by benchmarks/check_regression.py.

These tests are pure-stdlib (no JAX import) — splint analyzes source text.
"""
import sys
import textwrap
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from tools.splint import engine  # noqa: E402
from tools.splint.units import check_key_units, dimension_of  # noqa: E402


def rules_of(src, rule=None):
    findings = engine.scan_source(textwrap.dedent(src), "snippet.py")
    if rule is None:
        return findings
    return [f for f in findings if f.rule == rule]


# ---------------------------------------------------------------------------
# trace-safety
# ---------------------------------------------------------------------------


def test_trace_safety_flags_if_on_traced_value():
    found = rules_of("""
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """, "trace-safety")
    assert len(found) == 1 and "Python `if`" in found[0].message


def test_trace_safety_ok_static_args_and_shapes():
    found = rules_of("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("n",))
        def f(x, n):
            if n > 2 and x.ndim == 2:
                return x * x.shape[0]
            return x
    """, "trace-safety")
    assert found == []


def test_trace_safety_flags_host_cast_under_jit():
    found = rules_of("""
        import jax

        @jax.jit
        def f(x):
            y = x * 2
            return float(y)
    """, "trace-safety")
    assert len(found) == 1 and "float" in found[0].message


def test_trace_safety_flags_per_iteration_sync_in_loop():
    found = rules_of("""
        def run(fn, xs):
            out = []
            for x in xs:
                r = fn(x)
                out.append(float(r))
            return out
    """, "trace-safety")
    assert len(found) == 1 and "every loop iteration" in found[0].message


def test_trace_safety_ok_sync_after_loop():
    found = rules_of("""
        def run(fn, xs):
            out = []
            for x in xs:
                out.append(fn(x))
            return [float(r) for r in out]
    """, "trace-safety")
    assert found == []


# ---------------------------------------------------------------------------
# jit-hygiene
# ---------------------------------------------------------------------------


def test_jit_hygiene_flags_import_time_jnp():
    found = rules_of("""
        import jax.numpy as jnp

        TABLE = jnp.arange(16) * 2.0
    """, "jit-hygiene")
    assert len(found) == 1 and "import time" in found[0].message


def test_jit_hygiene_ok_numpy_constants_and_main_guard():
    found = rules_of("""
        import numpy as np
        import jax.numpy as jnp

        TABLE = np.arange(16) * 2.0

        def f():
            return jnp.asarray(TABLE)

        if __name__ == "__main__":
            print(jnp.arange(4))
    """, "jit-hygiene")
    assert found == []


def test_jit_hygiene_flags_jit_inside_loop():
    found = rules_of("""
        import jax

        def sweep(fns, x):
            outs = []
            for fn in fns:
                outs.append(jax.jit(fn)(x))
            return outs
    """, "jit-hygiene")
    assert len(found) == 1 and "inside a loop" in found[0].message


def test_jit_hygiene_flags_unknown_static_argname():
    found = rules_of("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("m",))
        def f(x, n):
            return x * n
    """, "jit-hygiene")
    assert len(found) == 1 and "no such parameter" in found[0].message


# ---------------------------------------------------------------------------
# pallas-block
# ---------------------------------------------------------------------------

_PALLAS_OK = """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def _k(x_ref, o_ref, acc_ref):
        i = pl.program_id(1)

        @pl.when(i == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += x_ref[...]
        o_ref[...] = acc_ref[...]

    def f(x):
        n = x.shape[0]
        bn = 128
        pad = (-n) % bn
        return pl.pallas_call(
            _k,
            grid=(4, n // bn),
            in_specs=[pl.BlockSpec((1, bn), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((1, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            scratch_shapes=[pltpu.VMEM((1, bn), jnp.float32)],
        )(x)
"""


def test_pallas_ok_well_formed_call():
    assert rules_of(_PALLAS_OK, "pallas-block") == []


def test_pallas_flags_index_map_arity():
    bad = _PALLAS_OK.replace("in_specs=[pl.BlockSpec((1, bn), "
                             "lambda i, j: (i, j))]",
                             "in_specs=[pl.BlockSpec((1, bn), "
                             "lambda i: (i, 0))]")
    found = rules_of(bad, "pallas-block")
    assert len(found) == 1 and "index map takes 1 args" in found[0].message


def test_pallas_flags_kernel_signature_mismatch():
    bad = _PALLAS_OK.replace("def _k(x_ref, o_ref, acc_ref):",
                             "def _k(x_ref, o_ref, acc_ref, extra_ref):")
    found = rules_of(bad, "pallas-block")
    assert any("takes 4 positional refs but pallas_call provides 3"
               in f.message for f in found)


def test_pallas_flags_unguarded_accumulator():
    bad = _PALLAS_OK.replace("@pl.when(i == 0)", "@pl.when(i == 1)")
    found = rules_of(bad, "pallas-block")
    assert len(found) == 1 and "acc_ref" in found[0].message \
        and "pl.when" in found[0].message


def test_pallas_flags_unguarded_griddiv():
    bad = _PALLAS_OK.replace("pad = (-n) % bn", "pad = 0")
    found = rules_of(bad, "pallas-block")
    assert len(found) == 1 and "floor-divides" in found[0].message


def test_pallas_flags_unaligned_tile():
    bad = _PALLAS_OK.replace("bn = 128", "bn = 200")
    found = rules_of(bad, "pallas-block")
    assert any("not lane-aligned" in f.message for f in found)


# ---------------------------------------------------------------------------
# unit-suffix
# ---------------------------------------------------------------------------


def test_unit_suffix_flags_mixed_addition():
    found = rules_of("""
        def cost(delay_s, energy_joules):
            return delay_s + energy_joules
    """, "unit-suffix")
    assert len(found) == 1 and "time[s]" in found[0].message \
        and "energy[J]" in found[0].message


def test_unit_suffix_flags_scale_mismatch_and_compare():
    src = """
        def f(a_ms, b_s, budget_joules):
            t = a_ms + b_s
            if b_s > budget_joules:
                return t
            return 0.0
    """
    found = rules_of(src, "unit-suffix")
    assert len(found) == 2


def test_unit_suffix_ok_same_dimension_and_rates():
    found = rules_of("""
        def f(up_s, down_s, link_bytes, rate_bytes_per_s):
            total_s = up_s + down_s
            t_s = link_bytes / rate_bytes_per_s
            return total_s + t_s
    """, "unit-suffix")
    assert found == []
    assert dimension_of("rate_bytes_per_s") == "data[byte]/time[s]"


# ---------------------------------------------------------------------------
# prng-reuse
# ---------------------------------------------------------------------------


def test_prng_flags_reused_key():
    found = rules_of("""
        import jax

        def make(key):
            a = jax.random.normal(key, (4,))
            b = jax.random.uniform(key, (4,))
            return a, b
    """, "prng-reuse")
    assert len(found) == 1 and "already consumed" in found[0].message


def test_prng_ok_split_keys_and_exclusive_branches():
    found = rules_of("""
        import jax

        def make(key, mode):
            keys = jax.random.split(key, 2)
            a = jax.random.normal(keys[0], (4,))
            if mode == "u":
                b = jax.random.uniform(keys[1], (4,))
            else:
                b = jax.random.normal(keys[1], (4,))
            return a, b
    """, "prng-reuse")
    assert found == []


def test_prng_flags_unsplit_key_in_loop():
    found = rules_of("""
        import jax

        def draws(key, n):
            out = []
            for _ in range(n):
                out.append(jax.random.normal(key, (4,)))
            return out
    """, "prng-reuse")
    assert len(found) == 1 and "loop" in found[0].message


def test_prng_ok_resplit_in_loop():
    found = rules_of("""
        import jax

        def draws(key, n):
            out = []
            for _ in range(n):
                key, sub = jax.random.split(key)
                out.append(jax.random.normal(sub, (4,)))
            return out
    """, "prng-reuse")
    assert found == []


def test_prng_ignores_stdlib_random():
    found = rules_of("""
        import random

        def jitter():
            return random.uniform(0.0, 1.0) + random.uniform(0.0, 1.0)
    """, "prng-reuse")
    assert found == []


# ---------------------------------------------------------------------------
# dtype-promo
# ---------------------------------------------------------------------------


def test_dtype_promo_flags_strong_numpy_scalar():
    found = rules_of("""
        import numpy as np

        def scale(x):
            return np.float64(0.5) * x
    """, "dtype-promo")
    assert len(found) == 1 and "strong-typed" in found[0].message


def test_dtype_promo_flags_untyped_scalar_array():
    found = rules_of("""
        import jax.numpy as jnp

        def scale(x):
            return x * jnp.array(0.5)
    """, "dtype-promo")
    assert len(found) == 1 and "without dtype=" in found[0].message


def test_dtype_promo_ok_weak_python_literal():
    found = rules_of("""
        import jax.numpy as jnp

        def scale(x):
            return 0.5 * x + jnp.array(0.5, dtype=x.dtype)
    """, "dtype-promo")
    assert found == []


# ---------------------------------------------------------------------------
# fault-hygiene
# ---------------------------------------------------------------------------


def test_fault_hygiene_flags_bare_except_and_silent_swallow():
    found = rules_of("""
        def fetch(link):
            try:
                return link.recv()
            except:
                return None

        def poll(link):
            try:
                link.ping()
            except Exception:
                pass
    """, "fault-hygiene")
    assert len(found) == 2
    assert "bare `except:`" in found[0].message
    assert "pass-only" in found[1].message


def test_fault_hygiene_ok_narrow_or_handled_except():
    found = rules_of("""
        def fetch(link, log):
            try:
                return link.recv()
            except TimeoutError:
                pass                      # narrow type: fine even pass-only
            except Exception as e:
                log.warning("recv failed: %s", e)
                raise
    """, "fault-hygiene")
    assert found == []


def test_fault_hygiene_flags_unsuffixed_timeout_bindings():
    found = rules_of("""
        timeout = 30
        DEADLINE: float = 2.5

        def wait(link, poll_timeout=0.1):
            link.recv(deadline=5.0)
    """, "fault-hygiene")
    assert len(found) == 4
    assert all("unit suffix" in f.message for f in found)


def test_fault_hygiene_ok_suffixed_or_nonnumeric():
    found = rules_of("""
        timeout_s = 30.0
        deadline_ms: float = 2500.0

        def wait(link, poll_timeout_s=0.1, deadline=None):
            link.recv(deadline=deadline, timeout=compute_budget())
            settings(deadline=None)
            flag = True
            hard_timeout = is_hard()      # not a literal
    """, "fault-hygiene")
    assert found == []


# ---------------------------------------------------------------------------
# doc-hygiene
# ---------------------------------------------------------------------------

_CORE_PATH = "src/repro/core/snippet.py"


def _doc_findings(src, path=_CORE_PATH):
    findings = engine.scan_source(textwrap.dedent(src), path)
    return [f for f in findings if f.rule == "doc-hygiene"]


def test_doc_hygiene_flags_undocumented_core_surface():
    found = _doc_findings("""
        import numpy as np

        def round_delay_s(d_flops, f_hz):
            total = d_flops / f_hz
            return total

        class FleetThing:
            x: int = 0
            y: int = 1
    """)
    messages = [f.message for f in found]
    assert any("module has no docstring" in m for m in messages)
    assert any("'round_delay_s'" in m for m in messages)
    assert any("'FleetThing'" in m for m in messages)
    assert len(found) == 3


def test_doc_hygiene_ok_documented_private_or_trivial():
    found = _doc_findings('''
        """Module contract lives here."""

        def round_delay_s(d_flops, f_hz):
            """Round delay in seconds for d_flops work at f_hz."""
            return d_flops / f_hz

        def _helper(x):
            y = x + 1
            return y

        def alias(x):
            return round_delay_s(x, 1.0)

        class Fleet:
            """Documented class; methods are exempt."""

            def undocumented_method(self):
                z = 1
                return z
    ''')
    assert found == []


def test_doc_hygiene_scoped_to_core_paths():
    src = """
        def undocumented(x):
            y = x + 1
            return y
    """
    assert _doc_findings(src, path="benchmarks/snippet.py") == []
    assert len(_doc_findings(src, path=_CORE_PATH)) == 2  # module + def


# ---------------------------------------------------------------------------
# pragmas / baseline / report
# ---------------------------------------------------------------------------

_NOISY = """\
import jax

@jax.jit
def f(x):
    return float(x)
"""


def _suppressed(src):
    findings = engine.scan_source(src, "snippet.py")
    pragmas = engine.Pragmas(src.splitlines())
    return [f for f in findings if not pragmas.suppresses(f)]


def test_pragma_same_line():
    src = _NOISY.replace("return float(x)",
                         "return float(x)  # splint: ignore[trace-safety]")
    assert engine.scan_source(src, "x.py") != []
    assert _suppressed(src) == []


def test_pragma_comment_line_above():
    src = _NOISY.replace(
        "    return float(x)",
        "    # splint: ignore[trace-safety] -- justification here\n"
        "    return float(x)")
    assert _suppressed(src) == []


def test_pragma_wrong_rule_does_not_suppress():
    src = _NOISY.replace("return float(x)",
                         "return float(x)  # splint: ignore[unit-suffix]")
    assert len(_suppressed(src)) == 1


def test_pragma_ignore_file():
    src = "# splint: ignore-file[trace-safety]\n" + _NOISY
    assert _suppressed(src) == []


def test_baseline_counts_ratchet():
    findings = engine.scan_source(_NOISY + _NOISY.replace("def f", "def g"),
                                  "x.py")
    assert len(findings) == 2
    baseline = {findings[0].fingerprint: 1}
    new, old = engine.split_new(findings, baseline)
    assert len(old) == 1 and len(new) == 1


def test_baseline_roundtrip(tmp_path):
    findings = engine.scan_source(_NOISY, "x.py")
    p = tmp_path / "baseline.json"
    engine.write_baseline(p, findings)
    assert engine.load_baseline(p) == {findings[0].fingerprint: 1}
    new, old = engine.split_new(findings, engine.load_baseline(p))
    assert new == [] and len(old) == 1


def test_report_schema(tmp_path):
    src_file = tmp_path / "mod.py"
    src_file.write_text(_NOISY)
    result = engine.scan_files([str(tmp_path)])
    report = engine.report_dict(result, result.findings, [])
    assert report["schema"] == "splint-report/v1"
    assert report["counts"]["new"] == 1
    assert report["new"][0]["rule"] == "trace-safety"


def test_repo_src_is_clean():
    """The acceptance criterion: 0 unsuppressed findings on src/."""
    result = engine.scan_files([str(REPO_ROOT / "src")])
    assert [f.format() for f in result.findings] == []


# ---------------------------------------------------------------------------
# payload-key units (benchmarks/check_regression.py wiring)
# ---------------------------------------------------------------------------


def test_key_units_accepts_repo_gate_keys():
    keys = ["probe_lora_matmul_128x128x128r8_s", "batched_card_round_s_5dev",
            "batched_card_round_s_1000dev_big", "mean_energy_j"]
    assert check_key_units(keys) == []
    assert check_key_units(keys[:3], require="time[s]") == []


def test_key_units_rejects_alias_suffix():
    errs = check_key_units(["round_secs"])
    assert len(errs) == 1 and "'secs'" in errs[0]


def test_key_units_rejects_mixed_dimensions():
    errs = check_key_units(["energy_joules_per_round_s"])
    assert errs and "mixes unit suffixes" in errs[0]


def test_key_units_require_dimension():
    errs = check_key_units(["gate_speedup"], require="time[s]")
    assert len(errs) == 1 and "no unit suffix" in errs[0]
