"""Multi-tenant serving: adapter banks, chunked prefill, admission control.

The load-bearing equivalences: (1) batched multi-adapter decode is
token-identical to a per-request single-adapter run; (2) chunked prefill
(parallel for attention families, decode-scan for SSM) is token-identical
to the token-by-token feed; (3) slot recycling never perturbs a neighbor's
in-flight lanes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.serve import generate
from repro.models import model as M
from repro.serving import (AdapterBank, ChannelAdmissionController, Request,
                           ServingEngine)

ARCHS = ["qwen3-0.6b", "mamba2-370m"]       # dense + SSM families


@pytest.fixture(scope="module", params=ARCHS)
def setup(request):
    cfg = get_config(request.param).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    adapters = [M.init_params(jax.random.PRNGKey(s), cfg)["lora"]
                for s in (0, 7, 13)]
    return cfg, params, adapters


def _mk_requests(cfg, n, seed=3, max_new=4):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 4 + (i % 3),
                                        dtype=np.int32).astype(np.int32),
                    max_new=max_new, adapter_id=i % 3) for i in range(n)]


def test_multi_adapter_matches_single_adapter_runs(setup):
    """One tick serving N users x N adapters == N per-request runs, each
    with only its own adapter. Token-identical, both families."""
    cfg, params, adapters = setup
    eng = ServingEngine(cfg, params["frozen"], AdapterBank(adapters),
                        slots=3, max_len=32, prefill_chunk=4)
    reqs = _mk_requests(cfg, 6)
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats["completed"] == 6 and stats["drained"]
    for r in reqs:
        want = np.asarray(generate(cfg, params["frozen"],
                                   adapters[r.adapter_id],
                                   jnp.asarray(r.prompt)[None], max_new=4))[0]
        np.testing.assert_array_equal(np.asarray(r.output), want,
                                      err_msg=f"uid={r.uid}")


def test_chunked_prefill_matches_token_by_token(setup):
    """Chunked prefill engines emit the same tokens as prefill_chunk=0
    (pure token-by-token feed), and actually run jitted prefill steps."""
    cfg, params, adapters = setup
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (11,), 0,
                                           cfg.vocab_size), np.int32)
    outs = {}
    for chunk in (0, 4):
        eng = ServingEngine(cfg, params["frozen"], adapters[1], slots=2,
                            max_len=32, prefill_chunk=chunk)
        eng.submit(Request(uid=0, prompt=prompt, max_new=5))
        stats = eng.run_until_drained()
        assert stats["completed"] == 1
        assert stats["prefills"] == (2 if chunk else 0)
        outs[chunk] = list(eng.completed[0].output)
    assert outs[0] == outs[4]


def test_prefill_chunk_logits_match_decode_loop(setup):
    """Model-level check: the jitted multi-token prefill reproduces the
    sequential decode loop's logits AND cache (full chunks only)."""
    cfg, params, _ = setup
    frozen, lora = params["frozen"], params["lora"]
    B, L, S = 2, 16, 8
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, S), 0,
                              cfg.vocab_size)
    cache = M.init_cache(cfg, B, L)
    want = None
    for t in range(S):
        want, cache = M.decode_step(frozen, lora, cache, toks[:, t:t + 1],
                                    t, cfg)
    ref_cache = cache

    cache2 = M.init_cache(cfg, B, L)
    if cfg.has_ssm:
        got, cache2 = M.decode_scan(frozen, lora, cache2, toks, 0, cfg)
        atol = 1e-5                       # same op sequence, scan-carried
    else:
        half = S // 2                     # two chunks exercise cross-chunk
        _, cache2 = M.prefill_chunk(frozen, lora, cache2, toks[:, :half],
                                    0, cfg)
        got, cache2 = M.prefill_chunk(frozen, lora, cache2, toks[:, half:],
                                      half, cfg)
        atol = 2e-4                       # parallel matmul re-association
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=atol)
    for a, b in zip(jax.tree_util.tree_leaves(ref_cache),
                    jax.tree_util.tree_leaves(cache2), strict=True):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)


def test_slot_recycling_does_not_perturb_neighbor(setup):
    """While slot A is mid-generation, recycling slot B (finish + admit a
    new request with a different adapter) must not change A's tokens."""
    cfg, params, adapters = setup
    bank = AdapterBank(adapters)
    rng = np.random.default_rng(9)
    long_prompt = rng.integers(0, cfg.vocab_size, 5, dtype=np.int32)
    short_prompt = rng.integers(0, cfg.vocab_size, 3, dtype=np.int32)

    # solo run: the long request alone
    solo = ServingEngine(cfg, params["frozen"], bank, slots=2, max_len=64)
    solo.submit(Request(uid=0, prompt=long_prompt, max_new=12, adapter_id=0))
    solo.run_until_drained()
    want = list(solo.completed[0].output)

    # contended run: neighbor slot churns through short requests (each
    # finishing triggers admission/recycling) while the long one decodes
    eng = ServingEngine(cfg, params["frozen"], bank, slots=2, max_len=64)
    eng.submit(Request(uid=0, prompt=long_prompt, max_new=12, adapter_id=0))
    for i in range(1, 4):
        eng.submit(Request(uid=i, prompt=short_prompt, max_new=2,
                           adapter_id=i % 3))
    stats = eng.run_until_drained()
    assert stats["completed"] == 4
    long_req = next(r for r in eng.completed if r.uid == 0)
    assert list(long_req.output) == want


def test_adapter_id_validated_at_submit(setup):
    cfg, params, adapters = setup
    eng = ServingEngine(cfg, params["frozen"], AdapterBank(adapters),
                        slots=1, max_len=32)
    with pytest.raises(ValueError, match="adapter_id"):
        eng.submit(Request(uid=0, prompt=np.asarray([1, 2], np.int32),
                           max_new=1, adapter_id=3))


def test_kernel_routed_decode_matches_jnp(setup):
    """use_lora_kernel=True routes per-slot adapters through the grouped
    Pallas kernel (interpret mode on CPU); logits must match the jnp path."""
    cfg, params, adapters = setup
    frozen = params["frozen"]
    bank = AdapterBank(adapters)
    B, L = 3, 8
    ids = jnp.asarray([2, 0, 1], jnp.int32)
    lora_b = AdapterBank.gather(bank.stacked, ids)
    toks = jax.random.randint(jax.random.PRNGKey(8), (B, 1), 0,
                              cfg.vocab_size)
    ts = jnp.zeros((B,), jnp.int32)
    cache = M.init_cache(cfg, B, L)
    want, _ = M.decode_step(frozen, lora_b, cache, toks, ts, cfg)
    got, _ = M.decode_step(frozen, lora_b, cache, toks, ts, cfg,
                           use_lora_kernel=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


# ---------------------------------------------------------------------------
# Channel-aware admission
# ---------------------------------------------------------------------------


def test_admission_blocks_then_releases(setup):
    """With capacity for ~1 stream, the pool serializes: blocked attempts
    are recorded, every grant is released, and the run still drains."""
    cfg, params, adapters = setup
    ctl = ChannelAdmissionController(
        bandwidth_hz=4e4, training_reserve_frac=0.5,
        token_rate_per_s=2000.0, bits_per_token=32.0, seed=0)
    eng = ServingEngine(cfg, params["frozen"], AdapterBank(adapters),
                        slots=3, max_len=32, admission=ctl)
    for r in _mk_requests(cfg, 5, max_new=3):
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats["completed"] == 5 and stats["drained"]
    adm = stats["admission"]
    assert adm["in_flight"] == 0
    assert adm["used_hz"] == 0.0
    tenants = adm["tenants"]
    assert sum(t["admitted"] for t in tenants.values()) == 5
    assert sum(t["completed"] for t in tenants.values()) == 5
    # the tight budget must actually have caused queueing
    assert (sum(t["blocked_attempts"] for t in tenants.values()) > 0
            or adm["forced_admits"] > 0)
    for t in tenants.values():
        assert t["mean_wait_s"] is None or t["mean_wait_s"] >= 0.0


def test_admission_wide_open_never_blocks():
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ctl = ChannelAdmissionController(bandwidth_hz=20e6,
                                     training_reserve_frac=0.5,
                                     token_rate_per_s=20.0, seed=1)
    eng = ServingEngine(cfg, params["frozen"], params["lora"], slots=2,
                        max_len=32, admission=ctl)
    for r in _mk_requests(cfg, 4, max_new=2):
        r.adapter_id = 0
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats["completed"] == 4 and stats["drained"]
    adm = stats["admission"]
    assert adm["forced_admits"] == 0
    assert all(t["blocked_attempts"] == 0 for t in adm["tenants"].values())
