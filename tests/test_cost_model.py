"""Cost model (Sec. III): FLOPs accounting, data sizes, delay/energy laws."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.core.channel import ChannelState
from repro.core.cost_model import RoundContext, Workload
from repro.core.hardware import (DEFAULT_SIM, EDGE_FLEET, SERVER_RTX4060TI,
                                 SimParams)

CFG = get_config("llama32-1b")


def ctx_for(cfg=CFG, batch=4, seq=512, device=EDGE_FLEET[0]):
    ch = ChannelState(25.0, 30.0, 20e6)
    return RoundContext(workload=Workload(cfg, batch, seq), device=device,
                        server=SERVER_RTX4060TI, channel=ch, sim=DEFAULT_SIM)


def test_eta_monotone_and_consistent():
    w = Workload(CFG, 4, 512)
    prev = -1.0
    for c in range(CFG.n_layers + 1):
        eta_d = w.device_flops(c)
        assert eta_d > prev
        prev = eta_d
        assert w.server_flops(c) == pytest.approx(
            w.total_flops() - eta_d)
    # eta_D(I) < eta: the head + loss always stay on the server
    assert w.device_flops(CFG.n_layers) < w.total_flops()


def test_uniform_layer_increments():
    """The paper's premise: every decoder layer adds the same FLOPs/bytes."""
    w = Workload(CFG, 4, 512)
    inc = [w.device_flops(c + 1) - w.device_flops(c)
           for c in range(CFG.n_layers)]
    assert np.allclose(inc, inc[0])
    sizes = [w.smashed_bytes(c, 2) for c in range(CFG.n_layers + 1)]
    assert len(set(sizes)) == 1  # constant smashed size across cuts
    ad = [w.adapter_bytes(c + 1, 4) - w.adapter_bytes(c, 4)
          for c in range(CFG.n_layers)]
    assert np.allclose(ad, ad[0]) and ad[0] > 0


def test_moe_counts_active_flops_only():
    moe = get_config("kimi-k2-1t-a32b")
    w = Workload(moe, 1, 128)
    per_layer = w.device_flops(1) - w.device_flops(0)
    # an active-FLOPs layer is ~ top_k/n_experts of a dense-all-experts layer
    dense_equiv = 2 * 2 * 3 * moe.d_model * moe.d_ff * moe.n_experts * 128
    assert per_layer < 0.1 * dense_equiv


@settings(max_examples=30, deadline=None)
@given(c=st.integers(0, 32), f_ghz=st.floats(0.5, 2.4))
def test_delay_energy_laws(c, f_ghz):
    """Eq. 8: server delay ~ 1/f. Eq. 11: energy ~ f^2 (same cut)."""
    ctx = ctx_for()
    f = f_ghz * 1e9
    d1 = ctx.server_comp_delay(c, f)
    d2 = ctx.server_comp_delay(c, 2 * f)
    assert d1 == pytest.approx(2 * d2, rel=1e-9)
    e1 = ctx.server_energy(c, f)
    e2 = ctx.server_energy(c, 2 * f)
    if e1 > 0:
        assert e2 == pytest.approx(4 * e1, rel=1e-9)


def test_transmission_delay_decomposition():
    """Eq. 9: T*(smashed up + grad down) + adapters both ways."""
    ctx = ctx_for()
    sim, ch, w = ctx.sim, ctx.channel, ctx.workload
    c = 7
    expect = (sim.local_epochs
              * (8 * sim.phi * w.smashed_bytes(c, sim.act_bytes) / ch.rate_up
                 + 8 * sim.phi * w.gradient_bytes(c, sim.act_bytes)
                 / ch.rate_down)
              + 8 * w.adapter_bytes(c, sim.adapter_bytes)
              * (1 / ch.rate_up + 1 / ch.rate_down))
    assert ctx.transmission_delay(c) == pytest.approx(expect)


def test_corners_ordering():
    for device in EDGE_FLEET:
        ctx = ctx_for(device=device)
        d_min, d_max, e_min, e_max = ctx.corners()
        assert d_min < d_max
        assert e_min < e_max
        # c=I leaves only the LM head + loss on the server (the paper treats
        # this as ~0; we count it): E_min must be a small fraction of E_max
        assert e_min < 0.05 * e_max
        # cost at the corners is within [0, 1] per term
        f = ctx.server.f_max
        assert 0.0 <= ctx.cost(0, f) <= 2.0


def test_fmin_scales_with_device_power():
    """F_min^{m,S} = f_m delta_m sigma_m / (delta_S sigma_S) (Sec. III-C)."""
    fmins = [ctx_for(device=d).f_min() for d in EDGE_FLEET]
    assert fmins == sorted(fmins, reverse=True)  # faster device, higher floor
    d = EDGE_FLEET[0]
    expect = d.peak_flops / (SERVER_RTX4060TI.delta * SERVER_RTX4060TI.sigma)
    assert fmins[0] == pytest.approx(max(expect, SERVER_RTX4060TI.f_min))


def test_memory_feasibility_mask():
    w = Workload(get_config("phi3-medium-14b"), 1, 128)
    ctx = RoundContext(workload=w, device=EDGE_FLEET[4],  # 4 GB Nano
                       server=SERVER_RTX4060TI,
                       channel=ChannelState(25, 30, 20e6), sim=DEFAULT_SIM)
    # 14B backbone (~29 GB bf16) cannot fit a 4 GB device beyond a few cuts
    assert ctx.max_feasible_cut() <= 4

    w2 = Workload(get_config("qwen3-0.6b"), 1, 128)
    ctx2 = RoundContext(workload=w2, device=EDGE_FLEET[0],  # 32 GB Orin
                        server=SERVER_RTX4060TI,
                        channel=ChannelState(25, 30, 20e6), sim=DEFAULT_SIM)
    assert ctx2.max_feasible_cut() == w2.cfg.n_layers
