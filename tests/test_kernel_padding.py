"""Block-shape edge cases: sequence lengths not divisible by the Pallas
block size, exercising the pad-to-multiple + mask path that splint's
grid-divisibility detector reasons about (`(-s) % block` guards in
flash_attention/flash_decode/lora_matmul/ssd_scan).

Each case pins the ragged geometry explicitly: one element past a block
boundary, one element short, a window crossing the padded tail, and the
partial-final-block decode slots.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.attention import naive_attention


def _qkv(key, b, sq, skv, hq, hkv, d):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, sq, hq, d))
    k = jax.random.normal(kk, (b, skv, hkv, d))
    v = jax.random.normal(kv, (b, skv, hkv, d))
    return q, k, v


@pytest.mark.parametrize("s,window", [
    (65, 0),     # one past the block boundary: 1-row ragged tail
    (63, 0),     # one short: single partial block, no full block
    (130, 64),   # window crosses the padded tail of the last KV block
    (127, 32),   # partial final block + window entirely inside it
])
def test_flash_attention_ragged_seq(s, window):
    q, k, v = _qkv(jax.random.PRNGKey(10), 2, s, s, 4, 4, 16)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    pos = jnp.broadcast_to(jnp.arange(s), (2, s))
    want = naive_attention(q, k, v, causal=True, window=window,
                           q_positions=pos, k_positions=pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_gqa_ragged():
    q, k, v = _qkv(jax.random.PRNGKey(11), 1, 65, 65, 8, 2, 16)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    pos = jnp.broadcast_to(jnp.arange(65), (1, 65))
    want = naive_attention(q, k, v, causal=True, window=0,
                           q_positions=pos, k_positions=pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_block_larger_than_seq():
    # block is clamped to the sequence: no padding at all
    q, k, v = _qkv(jax.random.PRNGKey(12), 2, 40, 40, 4, 4, 16)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    pos = jnp.broadcast_to(jnp.arange(40), (2, 40))
    want = naive_attention(q, k, v, causal=True, window=0,
                           q_positions=pos, k_positions=pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("s,t,block_k", [
    (70, 69, 64),   # t lands in the 6-slot partial final block
    (70, 64, 64),   # t is the first slot of the partial block
    (70, 63, 64),   # valid slots end exactly at the block boundary
    (33, 32, 64),   # cache smaller than the block: block clamps, no pad
])
def test_flash_decode_ragged_cache(s, t, block_k):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(13), 3)
    b, hq, hkv, d = 2, 4, 2, 16
    q = jax.random.normal(kq, (b, 1, hq, d))
    k = jax.random.normal(kk, (b, s, hkv, d))
    v = jax.random.normal(kv, (b, s, hkv, d))
    got = ops.flash_decode(q, k, v, jnp.int32(t), block_k=block_k)
    pos = jnp.full((b, 1), t, jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    want = naive_attention(q, k, v, causal=True, window=0,
                           q_positions=pos, k_positions=kpos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_decode_ring_buffer_ragged():
    # window == slots ring buffer whose slot count is not a block multiple
    s, window, t, block_k = 48, 48, 100, 32
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(14), 3)
    b, hq, hkv, d = 1, 4, 4, 16
    q = jax.random.normal(kq, (b, 1, hq, d))
    k = jax.random.normal(kk, (b, s, hkv, d))
    v = jax.random.normal(kv, (b, s, hkv, d))
    got = ops.flash_decode(q, k, v, jnp.int32(t), window=window,
                           block_k=block_k)
    j = jnp.arange(s, dtype=jnp.int32)
    abs_pos = t - ((t - j) % s)
    abs_pos = jnp.where(abs_pos >= 0, abs_pos, 2 ** 30)
    pos = jnp.full((b, 1), t, jnp.int32)
    want = naive_attention(q, k, v, causal=True, window=window,
                           q_positions=pos,
                           k_positions=jnp.broadcast_to(abs_pos, (b, s)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_lora_matmul_prime_shapes():
    # every dimension ragged against every block
    m, k, n, r = 37, 53, 41, 3
    kk = jax.random.split(jax.random.PRNGKey(15), 4)
    x = jax.random.normal(kk[0], (m, k))
    w = jax.random.normal(kk[1], (k, n))
    a = jax.random.normal(kk[2], (k, r))
    b = jax.random.normal(kk[3], (r, n))
    got = ops.lora_matmul(x, w, a, b, 0.25, bm=32, bn=32, bk=32)
    want = ref.lora_matmul_ref(x, w, a, b, 0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_ssd_scan_ragged_chunks():
    l, nh, hp, ns, chunk = 70, 2, 16, 16, 32   # 70 = 2 chunks + 6 tail
    kk = jax.random.split(jax.random.PRNGKey(16), 4)
    xt = jax.random.normal(kk[0], (2, l, nh, hp)) * 0.2
    a = -jnp.abs(jax.random.normal(kk[1], (2, l, nh))) * 0.1
    B = jax.random.normal(kk[2], (2, l, ns)) * 0.3
    C = jax.random.normal(kk[3], (2, l, ns)) * 0.3
    y1, h1 = ops.ssd_scan(xt, a, B, C, chunk)
    from repro.models.mamba import ssd_chunked
    y2, h2 = ssd_chunked(xt, a, B, C, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)
