"""Substrate tests: optimizer, checkpointing, data pipeline, LoRA math."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs.base import get_config
from repro.data import make_fleet_datasets, synthetic_lm_task
from repro.models.common import init_lora_pair, lora_dense
from repro.optim import (adamw, apply_updates, constant_schedule,
                         cosine_schedule, sgd, warmup_cosine)


# --- optimizer ---------------------------------------------------------------


def _quadratic_params():
    return {"w": jnp.array([3.0, -2.0]), "b": jnp.array([1.5])}


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(constant_schedule(0.1)),
    lambda: adamw(constant_schedule(0.1)),
])
def test_optimizers_minimize_quadratic(make_opt):
    opt = make_opt()
    params = _quadratic_params()
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-2


def test_schedules():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.int32(0))) == pytest.approx(0.0)
    assert float(s(jnp.int32(10))) == pytest.approx(1.0, rel=0.2)
    assert float(s(jnp.int32(100))) < 0.3
    c = cosine_schedule(2.0, 50)
    assert float(c(jnp.int32(0))) == pytest.approx(2.0)


def test_adamw_weight_decay():
    opt = adamw(constant_schedule(0.1), weight_decay=0.5)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    g = {"w": jnp.zeros((4,))}
    upd, _ = opt.update(g, state, params)
    assert float(upd["w"][0]) < 0  # pure decay pulls towards zero


# --- checkpoint --------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                   "c": jnp.zeros((2,), jnp.int32)},
    }
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, tree, step=17)
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, step = load_checkpoint(path, like)
    assert step == 17
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored), strict=True):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, {"a": jnp.ones((2,))})
    with pytest.raises(ValueError):
        load_checkpoint(path, {"a": jax.ShapeDtypeStruct((3,), jnp.float32)})


# --- data pipeline -----------------------------------------------------------


def test_transition_matrix_is_stochastic():
    p = synthetic_lm_task(64, seed=0)
    assert p.shape == (64, 64)
    np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-6)
    # the successor permutation dominates: the argmaxes form a bijection
    arg = p.argmax(-1)
    assert len(set(arg.tolist())) > 60
    # different seeds are genuinely different tasks
    p2 = synthetic_lm_task(64, seed=1)
    assert (p.argmax(-1) != p2.argmax(-1)).mean() > 0.9


def test_device_datasets_non_iid_but_shared_task():
    cfg = get_config("llama32-1b").reduced()
    ds = make_fleet_datasets(cfg, 3, vocab=64, seed=0)
    assert [d.noise for d in ds] == sorted({d.noise for d in ds})
    b = ds[0].minibatch(4, 16)
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    assert b["tokens"].dtype == np.int32
    # labels are next-token shifted inputs
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_embeds_frontend_stub():
    cfg = get_config("musicgen-large").reduced()
    ds = make_fleet_datasets(cfg, 1, vocab=cfg.vocab_size, seed=0)
    b = ds[0].minibatch(2, 8)
    assert "embeds" in b and b["embeds"].shape == (2, 8, cfg.d_model)
    assert b["embeds"].dtype == np.float32


# --- LoRA math ---------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(rank=st.integers(1, 8), scale=st.floats(0.1, 4.0))
def test_lora_dense_delta_rank(rank, scale):
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (16, 12))
    lora = init_lora_pair(jax.random.PRNGKey(1), 16, 12, rank)
    x = jax.random.normal(jax.random.PRNGKey(2), (5, 16))
    base = lora_dense(x, w, None, scale)
    # B initialized to zero => no initial delta (standard LoRA)
    np.testing.assert_allclose(np.asarray(lora_dense(x, w, lora, scale)),
                               np.asarray(base), atol=1e-6)
    # after perturbing B the delta has rank <= r
    lora = {"a": lora["a"],
            "b": jax.random.normal(jax.random.PRNGKey(3), lora["b"].shape)}
    delta = np.asarray(lora_dense(x, w, lora, scale) - base)
    full_delta = np.asarray(x) @ (np.asarray(lora["a"])
                                  @ np.asarray(lora["b"])) * scale
    np.testing.assert_allclose(delta, full_delta, atol=1e-4)
