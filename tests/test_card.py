"""CARD algorithm: optimality, closed-form frequency, baselines.

Property tests (hypothesis) assert the system's invariants:
  * CARD == exhaustive (f, c) grid search (within grid resolution)
  * Eq. 16's closed-form f* is the argmin of the convex frequency subproblem
  * U is monotone: delay up => cost up (w fixed), energy up => cost up
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.core import card as C
from repro.core.channel import ChannelState, WirelessChannel
from repro.core.cost_model import RoundContext, Workload
from repro.core.hardware import (DEFAULT_SIM, EDGE_FLEET, SERVER_RTX4060TI,
                                 SimParams)

CFG = get_config("llama32-1b")


def make_ctx(device_idx=0, snr_up=25.0, snr_down=30.0, w=0.2,
             batch=4, seq=512, arch_cfg=None):
    sim = SimParams(w=w, mini_batch=batch, seq_len=seq)
    ch = ChannelState(snr_up_db=snr_up, snr_down_db=snr_down,
                      bandwidth_hz=sim.bandwidth_hz)
    return RoundContext(workload=Workload(arch_cfg or CFG, batch, seq),
                        device=EDGE_FLEET[device_idx],
                        server=SERVER_RTX4060TI, channel=ch, sim=sim)


@settings(max_examples=40, deadline=None)
@given(device_idx=st.integers(0, 4),
       snr_up=st.floats(-5, 40), snr_down=st.floats(-5, 40),
       w=st.floats(0.05, 0.95))
def test_card_matches_bruteforce(device_idx, snr_up, snr_down, w):
    ctx = make_ctx(device_idx, snr_up, snr_down, w)
    a = C.card(ctx)
    b = C.card_joint_bruteforce(ctx, n_freq=300)
    # closed-form f* beats (or ties) any gridded frequency
    assert a.cost <= b.cost + 1e-9
    assert 0 <= a.cut <= CFG.n_layers
    assert ctx.f_min() - 1e-6 <= a.frequency <= ctx.server.f_max + 1e-6


@settings(max_examples=40, deadline=None)
@given(device_idx=st.integers(0, 4), w=st.floats(0.05, 0.95),
       cut=st.integers(0, 32))
def test_frequency_closed_form_is_argmin(device_idx, w, cut):
    """Eq. 16: f* minimizes U(f | c) over the feasible interval."""
    ctx = make_ctx(device_idx, w=w)
    corners = ctx.corners()
    f_star = C.optimal_frequency(ctx)
    u_star = ctx.cost(cut, f_star, corners)
    for f in np.linspace(ctx.f_min(), ctx.server.f_max, 200):
        assert u_star <= ctx.cost(cut, float(f), corners) + 1e-9


def test_cost_monotonicity():
    ctx = make_ctx()
    corners = ctx.corners()
    f = C.optimal_frequency(ctx)
    # higher f: delay term down, energy term up (both strictly, c=0)
    d1, d2 = ctx.round_delay(0, f), ctx.round_delay(0, f * 1.2)
    e1, e2 = ctx.server_energy(0, f), ctx.server_energy(0, f * 1.2)
    assert d2 < d1 and e2 > e1
    # energy at full offload decreases with cut (less server work)
    assert ctx.server_energy(32, f) < ctx.server_energy(0, f)
    # device compute delay increases with cut
    assert ctx.device_comp_delay(32) > ctx.device_comp_delay(0)
    del corners


def test_bimodal_optimal_cut_uniform_stack():
    """Paper Fig. 3(a): uniform per-layer cost => optimum at an endpoint."""
    for device_idx in range(5):
        for seed in range(8):
            ch = WirelessChannel("normal", seed=seed).draw()
            sim = DEFAULT_SIM
            ctx = RoundContext(workload=Workload(CFG, sim.mini_batch,
                                                 sim.seq_len),
                               device=EDGE_FLEET[device_idx],
                               server=SERVER_RTX4060TI, channel=ch, sim=sim)
            d = C.card(ctx, respect_memory=False)
            assert d.cut in (0, CFG.n_layers), \
                f"non-endpoint cut {d.cut} for device{device_idx + 1}"


def test_weak_devices_prefer_offload():
    """Paper Fig. 3: device5 (weakest) must offload everything (c=0)."""
    ctx5 = make_ctx(device_idx=4)
    assert C.card(ctx5).cut == 0


def test_server_only_device_only_endpoints():
    ctx = make_ctx()
    assert C.server_only(ctx).cut == 0
    assert C.device_only(ctx).cut == CFG.n_layers
    # server-only burns the most server energy; device-only the least
    assert C.server_only(ctx).energy > C.card(ctx).energy
    assert C.device_only(ctx).energy <= C.card(ctx).energy + 1e-9


def test_memory_mask_forces_server_side_for_1t_model():
    """Kimi-1T cannot reside on a Jetson: CARD must pick c=0."""
    kimi = get_config("kimi-k2-1t-a32b")
    ctx = make_ctx(device_idx=0, arch_cfg=kimi, batch=1, seq=128)
    assert ctx.max_feasible_cut() == 0
    assert C.card(ctx).cut == 0


def test_q_formula_exact():
    """Q = cbrt(w (Emax-Emin) / (2 xi (1-w) (Dmax-Dmin))) before clipping."""
    ctx = make_ctx(w=0.5)
    d_min, d_max, e_min, e_max = ctx.corners()
    q = ((0.5 * (e_max - e_min))
         / (2 * ctx.sim.xi * 0.5 * (d_max - d_min))) ** (1 / 3)
    f = C.optimal_frequency(ctx)
    assert f == pytest.approx(
        float(np.clip(q, ctx.f_min(), ctx.server.f_max)), rel=1e-9)
