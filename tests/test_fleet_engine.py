"""Vectorized CARD fleet engine vs the scalar reference oracle.

The batched path must be a pure refactor of the decision layer: identical
channel realizations in, identical (cut, frequency) decisions out, for every
policy, architecture, and channel state — plus the new exact parallel-SL
round time must land inside the legacy upper/lower bounds.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.core import card as C
from repro.core.channel import (SEED_STRIDE, WirelessChannel,
                                draw_channel_matrix)
from repro.core.cost_model import BatchedRoundContext, RoundContext, Workload
from repro.core.hardware import (DEFAULT_SIM, EDGE_FLEET, SERVER_RTX4060TI,
                                 SimParams, make_heterogeneous_fleet)
from repro.core.scheduler import parallel_round_stats, simulate_fleet

ARCHS = ("llama32-1b", "qwen3-4b", "granite-moe-3b-a800m")
STATES = ("good", "normal", "poor")


def _assert_logs_match(a, b):
    assert np.array_equal(a.cuts, b.cuts)
    np.testing.assert_allclose(a.freqs, b.freqs, rtol=1e-5)
    np.testing.assert_allclose(a.delays, b.delays, rtol=1e-4)
    np.testing.assert_allclose(a.energies, b.energies, rtol=1e-4, atol=1e-6)
    for k in ("d_device", "d_uplink", "d_server", "d_downlink"):
        np.testing.assert_allclose(getattr(a, k), getattr(b, k), rtol=1e-4,
                                   atol=1e-9)


@pytest.mark.parametrize("state", STATES)
@pytest.mark.parametrize("arch", ARCHS)
def test_card_engines_equivalent(arch, state):
    """The acceptance bar: same (cut, f) decisions, 3 archs x 3 states."""
    cfg = get_config(arch)
    a = simulate_fleet(cfg, channel_state=state, rounds=6, seed=7,
                       engine="scalar")
    b = simulate_fleet(cfg, channel_state=state, rounds=6, seed=7,
                       engine="vectorized")
    _assert_logs_match(a, b)


@pytest.mark.parametrize("policy", ["server_only", "device_only", "static",
                                    "random"])
def test_baseline_engines_equivalent(policy):
    cfg = get_config("llama32-1b")
    kw = dict(policy=policy, rounds=4, seed=11,
              static_cut=9 if policy == "static" else None)
    _assert_logs_match(simulate_fleet(cfg, engine="scalar", **kw),
                       simulate_fleet(cfg, engine="vectorized", **kw))


# derandomize: the engines agree to float32 resolution, so a fresh random
# fleet each CI run could in principle hit a near-tied cost and flake;
# a fixed example sequence keeps the decision-identity check deterministic
@settings(max_examples=10, deadline=None, derandomize=True)
@given(n_devices=st.integers(2, 24), seed=st.integers(0, 999),
       state=st.sampled_from(STATES))
def test_batched_card_matches_scalar_on_random_fleets(n_devices, seed, state):
    """Property: decision-for-decision match on randomized heterogeneous
    fleets (platform mix and clock jitter drawn from the seed)."""
    cfg = get_config("llama32-1b")
    fleet = make_heterogeneous_fleet(n_devices, seed=seed)
    a = simulate_fleet(cfg, channel_state=state, rounds=3, seed=seed,
                       devices=fleet, engine="scalar")
    b = simulate_fleet(cfg, channel_state=state, rounds=3, seed=seed,
                       devices=fleet, engine="vectorized")
    _assert_logs_match(a, b)


def test_channel_matrix_matches_sequential_draws():
    """Batched sampling consumes the per-device PRNG streams in the exact
    order of sequential scalar draw() calls."""
    sim = DEFAULT_SIM
    batch = draw_channel_matrix("normal", 5, 3, seed=2,
                                bandwidth_hz=sim.bandwidth_hz)
    for m in range(3):
        ch = WirelessChannel("normal", seed=2 + SEED_STRIDE * m,
                             bandwidth_hz=sim.bandwidth_hz)
        for r in range(5):
            s = ch.draw()
            assert batch.snr_up_db[r, m] == pytest.approx(s.snr_up_db)
            assert batch.snr_down_db[r, m] == pytest.approx(s.snr_down_db)
            assert batch.state(r, m).rate_up == pytest.approx(s.rate_up)


def test_delay_components_sum_to_round_delay():
    cfg = get_config("llama32-1b")
    sim = DEFAULT_SIM
    ctx = RoundContext(workload=Workload(cfg, sim.mini_batch, sim.seq_len),
                       device=EDGE_FLEET[1], server=SERVER_RTX4060TI,
                       channel=WirelessChannel("normal", seed=4).draw(),
                       sim=sim)
    for cut in (0, 7, cfg.n_layers):
        f = C.optimal_frequency(ctx)
        parts = ctx.delay_components(cut, f)
        assert parts.total == pytest.approx(ctx.round_delay(cut, f), rel=1e-12)


def test_parallel_exact_within_legacy_bounds():
    cfg = get_config("llama32-1b")
    for state in STATES:
        log = simulate_fleet(cfg, channel_state=state, rounds=8, seed=3)
        s = parallel_round_stats(log)
        assert (s["parallel_lower_s"] - 1e-9 <= s["parallel_exact_s"]
                <= s["parallel_upper_s"] + 1e-9), (state, s)
        # exact sequential time is the component sum too
        comp_sum = (log.d_device + log.d_uplink + log.d_server
                    + log.d_downlink)
        np.testing.assert_allclose(comp_sum, log.delays, rtol=1e-6)


def test_batched_card_beats_joint_grid():
    """Closed-form f* + cut argmin must never lose to the vmapped (f, c)
    exhaustive grid (it can only tie or win, by Eq. 16 convexity)."""
    cfg = get_config("qwen3-4b")
    sim = DEFAULT_SIM
    batch = draw_channel_matrix("normal", 3, len(EDGE_FLEET), seed=5,
                                bandwidth_hz=sim.bandwidth_hz,
                                tx_power_dbm_up=sim.tx_power_dbm_up,
                                tx_power_dbm_down=sim.tx_power_dbm_down,
                                noise_dbm_per_hz=sim.noise_dbm_per_hz)
    bctx = BatchedRoundContext.build(
        Workload(cfg, sim.mini_batch, sim.seq_len), EDGE_FLEET,
        SERVER_RTX4060TI, batch, sim)
    a = C.batched_card(bctx)
    g = C.batched_card_joint_bruteforce(bctx, n_freq=60)
    assert np.all(np.asarray(a.costs) <= np.asarray(g.costs) + 1e-5)
    assert np.array_equal(np.asarray(a.cuts), np.asarray(g.cuts))


def test_memory_mask_batched_matches_scalar():
    """A 1T-param model can't fit any Jetson: every batched decision must
    respect the same per-device feasibility cap the scalar path derives."""
    kimi = get_config("kimi-k2-1t-a32b")
    sim = SimParams(mini_batch=1, seq_len=128)
    w = Workload(kimi, 1, 128)
    batch = draw_channel_matrix("normal", 2, len(EDGE_FLEET), seed=0,
                                bandwidth_hz=sim.bandwidth_hz)
    bctx = BatchedRoundContext.build(w, EDGE_FLEET, SERVER_RTX4060TI, batch,
                                     sim)
    for m, dev in enumerate(EDGE_FLEET):
        ctx = RoundContext(workload=w, device=dev, server=SERVER_RTX4060TI,
                           channel=batch.state(0, m), sim=sim)
        assert int(bctx.max_cut[m]) == ctx.max_feasible_cut()
    dec = C.batched_card(bctx)
    assert np.all(np.asarray(dec.cuts) == 0)


def test_thousand_device_round_end_to_end():
    """Acceptance: a 1000-device heterogeneous round runs end-to-end."""
    cfg = get_config("llama32-1b")
    fleet = make_heterogeneous_fleet(1000, seed=0)
    log = simulate_fleet(cfg, rounds=1, devices=fleet, seed=0)
    assert log.cuts.shape == (1, 1000)
    assert np.isfinite(log.delays).all() and np.isfinite(log.energies).all()
    assert (log.delays > 0).all()
    stats = parallel_round_stats(log)
    assert stats["parallel_exact_s"] >= stats["parallel_lower_s"] - 1e-9
