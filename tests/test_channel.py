"""Wireless channel: CQI/MCS mapping, pathloss states, fading draws."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.channel import (CQI_EFFICIENCY, CQI_SNR_THRESH_DB,
                                ChannelState, WirelessChannel, pathloss_db,
                                snr_to_efficiency)


def test_cqi_table_is_3gpp_38214():
    assert len(CQI_EFFICIENCY) == 15
    assert CQI_EFFICIENCY[0] == pytest.approx(0.1523)
    assert CQI_EFFICIENCY[-1] == pytest.approx(5.5547)
    assert list(CQI_EFFICIENCY) == sorted(CQI_EFFICIENCY)


@settings(max_examples=50, deadline=None)
@given(snr=st.floats(-20, 50))
def test_efficiency_monotone_in_snr(snr):
    e1 = snr_to_efficiency(snr)
    e2 = snr_to_efficiency(snr + 3.0)
    assert e2 >= e1
    assert 0.0 <= e1 <= CQI_EFFICIENCY[-1]


def test_efficiency_thresholds_exact():
    for thresh, eff in zip(CQI_SNR_THRESH_DB, CQI_EFFICIENCY, strict=True):
        assert snr_to_efficiency(thresh) == pytest.approx(eff)
        assert snr_to_efficiency(thresh - 0.01) < eff or eff == CQI_EFFICIENCY[0]


def test_pathloss_states_ordering():
    """Good(alpha=2) < Normal(4) < Poor(6) pathloss at the same distance."""
    good = WirelessChannel("good", fading=False)
    normal = WirelessChannel("normal", fading=False)
    poor = WirelessChannel("poor", fading=False)
    assert good.mean_snr_db(True) > normal.mean_snr_db(True) \
        > poor.mean_snr_db(True)
    r = [c.draw().rate_up for c in (good, normal, poor)]
    assert r[0] >= r[1] >= r[2] > 0  # floor at CQI-1 keeps rates positive


def test_fading_varies_rounds_deterministically():
    c1 = WirelessChannel("normal", seed=7)
    c2 = WirelessChannel("normal", seed=7)
    draws1 = [c1.draw().snr_up_db for _ in range(5)]
    draws2 = [c2.draw().snr_up_db for _ in range(5)]
    assert draws1 == draws2                 # reproducible
    assert len(set(draws1)) > 1             # but round-varying


def test_invalid_state_rejected():
    with pytest.raises(ValueError):
        WirelessChannel("excellent")


def test_rate_formula():
    st_ = ChannelState(snr_up_db=100.0, snr_down_db=100.0, bandwidth_hz=20e6)
    assert st_.rate_up == pytest.approx(20e6 * 5.5547)


def test_determinism_seam_draw_rounds_vs_matrix():
    """The seam both fleet engines (and the fault overlay) stand on:
    per-device streams seeded ``seed + SEED_STRIDE * m`` yield bit-identical
    realizations whether consumed one ``draw()`` at a time, in one
    ``draw_rounds`` block, or through ``draw_channel_matrix``."""
    from repro.core.channel import SEED_STRIDE, draw_channel_matrix
    rounds, n_dev, seed = 7, 4, 13
    batch = draw_channel_matrix("normal", rounds, n_dev, seed=seed)
    for m in range(n_dev):
        block = WirelessChannel("normal", seed=seed + SEED_STRIDE * m)
        up, down = block.draw_rounds(rounds)
        assert list(batch.snr_up_db[:, m]) == list(up)
        assert list(batch.snr_down_db[:, m]) == list(down)
        seq = WirelessChannel("normal", seed=seed + SEED_STRIDE * m)
        for r in range(rounds):
            # scalar draw() consumes the same stream; math.log10 vs np.log10
            # differ in the last ulp, so approx here (exact above)
            st_seq = seq.draw()
            assert st_seq.snr_up_db == pytest.approx(up[r], rel=1e-12)
            assert st_seq.snr_down_db == pytest.approx(down[r], rel=1e-12)
            st_mat = batch.state(r, m)
            assert st_mat.snr_up_db == batch.snr_up_db[r, m]
            assert st_mat.rate_up == pytest.approx(st_seq.rate_up, rel=1e-9)
