"""Serving engine: continuous batching correctness + throughput accounting."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.serve import generate
from repro.models import model as M
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_matches_generate_greedy(setup):
    cfg, params = setup
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (5,), 0,
                                           cfg.vocab_size), np.int32)
    want = np.asarray(generate(cfg, params["frozen"], params["lora"],
                               jnp.asarray(prompt)[None], max_new=6))[0]
    eng = ServingEngine(cfg, params["frozen"], params["lora"], slots=2,
                        max_len=32)
    eng.submit(Request(uid=0, prompt=prompt, max_new=6))
    stats = eng.run_until_drained()
    assert stats["completed"] == 1
    got = eng.completed[0].output
    np.testing.assert_array_equal(np.asarray(got), want)


def test_continuous_batching_multiplexes(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params["frozen"], params["lora"], slots=2,
                        max_len=32)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 3 + i,
                                               dtype=np.int32).astype(np.int32),
                    max_new=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats["completed"] == 5
    assert all(len(r.output) == 4 for r in eng.completed)
    # with 2 slots and 5 requests, batching must overlap: fewer ticks than
    # the fully sequential schedule
    seq_ticks = sum(len(r.prompt) + r.max_new - 1 for r in reqs)
    assert stats["ticks"] < seq_ticks


def test_slot_isolation(setup):
    """A recycled slot must not leak cache state into the next request."""
    cfg, params = setup
    prompt = np.asarray([7, 3, 11], np.int32)
    # run the same request twice through the same engine (slot reuse)...
    eng = ServingEngine(cfg, params["frozen"], params["lora"], slots=1,
                        max_len=32)
    eng.submit(Request(uid=0, prompt=prompt, max_new=5))
    eng.run_until_drained()
    first = list(eng.completed[0].output)
    eng.submit(Request(uid=1, prompt=prompt, max_new=5))
    eng.run_until_drained(max_ticks=20_000)
    second = list(eng.completed[1].output)
    assert first == second


# ---------------------------------------------------------------------------
# Regression tests for the four serving-engine bugs (ISSUE 9)
# ---------------------------------------------------------------------------


def test_admission_never_copies_the_cache(setup):
    """Bug 1: _admit used to rebuild the whole stacked cache per admitted
    slot. Admission must not touch cache buffers at all (lazy reset)."""
    cfg, params = setup
    eng = ServingEngine(cfg, params["frozen"], params["lora"], slots=2,
                        max_len=32)
    eng.submit(Request(uid=0, prompt=np.asarray([1, 2, 3], np.int32),
                       max_new=2))
    before = jax.tree_util.tree_leaves(eng.cache)
    eng._admit()
    assert not eng.slots[0].free          # the request was admitted...
    after = jax.tree_util.tree_leaves(eng.cache)
    assert all(a is b for a, b in zip(before, after, strict=True)), \
        "admission must not rebuild or copy any cache leaf"


def test_run_until_drained_reports_undrained(setup):
    """Bug 2: exiting on max_ticks silently reported stats as drained."""
    cfg, params = setup
    eng = ServingEngine(cfg, params["frozen"], params["lora"], slots=1,
                        max_len=32)
    prompt = np.asarray([5, 9, 2], np.int32)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=prompt, max_new=4))
    stats = eng.run_until_drained(max_ticks=6)
    assert stats["drained"] is False
    pend = stats["pending"]
    assert pend["queued"] + pend["in_flight"] + stats["completed"] == 3
    assert pend["queued"] + pend["in_flight"] > 0
    # ...and a full drain reports clean
    stats = eng.run_until_drained()
    assert stats["drained"] is True
    assert stats["pending"] == {"queued": 0, "in_flight": 0}


def test_submit_rejects_overflowing_request(setup):
    """Bug 3: len(prompt) + max_new > max_len used to decode past the cache
    end, where dynamic-update clamping corrupts the last lane."""
    cfg, params = setup
    eng = ServingEngine(cfg, params["frozen"], params["lora"], slots=1,
                        max_len=8)
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.submit(Request(uid=0, prompt=np.arange(6, dtype=np.int32),
                           max_new=3))
    assert not eng.queue
    # exact fit is accepted
    eng.submit(Request(uid=1, prompt=np.arange(6, dtype=np.int32), max_new=2))
    stats = eng.run_until_drained()
    assert stats["completed"] == 1 and len(eng.completed[0].output) == 2


def test_submit_truncates_with_flag(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params["frozen"], params["lora"], slots=1,
                        max_len=8, on_overflow="truncate")
    req = Request(uid=0, prompt=np.arange(6, dtype=np.int32), max_new=5)
    eng.submit(req)
    assert req.truncated and req.max_new == 2
    with pytest.raises(ValueError, match="alone exceeds"):
        eng.submit(Request(uid=1, prompt=np.arange(9, dtype=np.int32),
                           max_new=1))
    stats = eng.run_until_drained()
    assert stats["completed"] == 1 and len(req.output) == 2


def test_mean_ttft_none_when_no_first_tokens(setup):
    """Bug 4: np.mean([]) RuntimeWarning -> NaN when completed requests
    exist but none recorded a first token."""
    cfg, params = setup
    eng = ServingEngine(cfg, params["frozen"], params["lora"], slots=1,
                        max_len=32)
    # a request that was force-completed without ever emitting (e.g. by an
    # external cancel path) has first_token_at=None
    eng.completed.append(Request(uid=0, prompt=np.asarray([1], np.int32),
                                 max_new=1))
    with warnings.catch_warnings():
        warnings.simplefilter("error")            # RuntimeWarning -> failure
        stats = eng.run_until_drained()
    assert stats["completed"] == 1
    assert stats["mean_ttft_s"] is None
