"""Serving engine: continuous batching correctness + throughput accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.serve import generate
from repro.models import model as M
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_matches_generate_greedy(setup):
    cfg, params = setup
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (5,), 0,
                                           cfg.vocab_size), np.int32)
    want = np.asarray(generate(cfg, params["frozen"], params["lora"],
                               jnp.asarray(prompt)[None], max_new=6))[0]
    eng = ServingEngine(cfg, params["frozen"], params["lora"], slots=2,
                        max_len=32)
    eng.submit(Request(uid=0, prompt=prompt, max_new=6))
    stats = eng.run_until_drained()
    assert stats["completed"] == 1
    got = eng.completed[0].output
    np.testing.assert_array_equal(np.asarray(got), want)


def test_continuous_batching_multiplexes(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params["frozen"], params["lora"], slots=2,
                        max_len=32)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 3 + i,
                                               dtype=np.int32).astype(np.int32),
                    max_new=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats["completed"] == 5
    assert all(len(r.output) == 4 for r in eng.completed)
    # with 2 slots and 5 requests, batching must overlap: fewer ticks than
    # the fully sequential schedule
    seq_ticks = sum(len(r.prompt) + r.max_new - 1 for r in reqs)
    assert stats["ticks"] < seq_ticks


def test_slot_isolation(setup):
    """A recycled slot must not leak cache state into the next request."""
    cfg, params = setup
    prompt = np.asarray([7, 3, 11], np.int32)
    # run the same request twice through the same engine (slot reuse)...
    eng = ServingEngine(cfg, params["frozen"], params["lora"], slots=1,
                        max_len=32)
    eng.submit(Request(uid=0, prompt=prompt, max_new=5))
    eng.run_until_drained()
    first = list(eng.completed[0].output)
    eng.submit(Request(uid=1, prompt=prompt, max_new=5))
    eng.run_until_drained(max_ticks=20_000)
    second = list(eng.completed[1].output)
    assert first == second
