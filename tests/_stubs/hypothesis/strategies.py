"""Strategies for the vendored hypothesis fallback — just enough surface
for this repo's property tests: integers, floats, sampled_from."""
from typing import Callable, List, Sequence


class SearchStrategy:
    """Boundary examples first (index-addressed), then seeded-random draws."""

    def __init__(self, boundary: Sequence, sample: Callable):
        self._boundary: List = list(boundary)
        self._sample = sample

    def example(self, rng, index: int):
        if index < len(self._boundary):
            return self._boundary[index]
        return self._sample(rng)


def integers(min_value, max_value) -> SearchStrategy:
    return SearchStrategy([min_value, max_value],
                          lambda rng: rng.randint(min_value, max_value))


def floats(min_value, max_value, **_ignored) -> SearchStrategy:
    return SearchStrategy([min_value, max_value],
                          lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(elements[:1], lambda rng: rng.choice(elements))
