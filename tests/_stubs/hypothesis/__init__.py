"""Deterministic fallback for `hypothesis`, used ONLY when the real package
is not installed (the conftest inserts this directory into sys.path then).

CI installs the real hypothesis via ``pip install -e .[test]`` and never
sees this module. The fallback keeps the property-test modules collectable
and meaningfully exercised in hermetic environments: each ``@given`` test
runs ``max_examples`` times — boundary values first, then seeded-random
draws — so invariants still get a spread of inputs, just without real
shrinking or the example database.
"""
import functools
import inspect
import random

from . import strategies  # noqa: F401

__version__ = "0.0.0+stub"


class UnsatisfiedAssumption(Exception):
    pass


def assume(condition):
    if not condition:
        raise UnsatisfiedAssumption
    return True


class settings:
    """Decorator form only (``@settings(...)`` above ``@given``)."""

    def __init__(self, max_examples=20, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_max_examples = self.max_examples
        return fn


def given(*args, **param_strategies):
    if args:
        raise TypeError("hypothesis stub supports keyword strategies only")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*fargs, **fkwargs):
            n = getattr(wrapper, "_stub_max_examples", 20)
            rng = random.Random(fn.__qualname__)  # stable string seeding
            for i in range(n):
                drawn = {k: s.example(rng, i)
                         for k, s in param_strategies.items()}
                try:
                    fn(*fargs, **drawn, **fkwargs)
                except UnsatisfiedAssumption:
                    continue
        # hide strategy-filled params so pytest doesn't see them as fixtures
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in param_strategies])
        wrapper.is_hypothesis_stub = True
        return wrapper
    return deco
