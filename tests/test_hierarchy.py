"""Hierarchical multi-server CARD: assignment optimality, scalar-vs-batched
decision equivalence, and the S=1 degenerate case collapsing to the flat
batched engine."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import card as C
from repro.core.channel import draw_channel_matrix
from repro.core.cost_model import (BatchedRoundContext, TieredRoundContext,
                                   Workload)
from repro.core.hardware import (DEFAULT_SIM, EDGE_FLEET, SERVER_RTX4060TI,
                                 ServerTier, make_heterogeneous_fleet,
                                 make_server_tier, tier_arrays)
from repro.core.scheduler import simulate_hierarchical_fleet


def _tctx(n_devices=4, n_servers=2, rounds=3, *, capacity=None, seed=1,
          tier_seed=7, state="normal", arch="llama32-1b"):
    cfg = get_config(arch)
    sim = DEFAULT_SIM
    wl = Workload(cfg, sim.mini_batch, sim.seq_len)
    devices = (EDGE_FLEET * 2)[:n_devices] if n_devices <= 10 \
        else make_heterogeneous_fleet(n_devices, seed=seed)
    tier = make_server_tier(n_servers, capacity=capacity or n_devices,
                            seed=tier_seed)
    ch = draw_channel_matrix(state, rounds, len(devices), seed=seed,
                             bandwidth_hz=sim.bandwidth_hz)
    return wl, devices, tier, ch, sim, \
        TieredRoundContext.build(wl, devices, tier, ch, sim)


# --- ServerTier --------------------------------------------------------------


def test_server_tier_validation():
    with pytest.raises(ValueError):
        ServerTier(servers=(), capacity=(), backhaul_bits_per_s=())
    with pytest.raises(ValueError):
        ServerTier(servers=(SERVER_RTX4060TI,), capacity=(1, 2),
                   backhaul_bits_per_s=(1e9,))
    with pytest.raises(ValueError):
        ServerTier(servers=(SERVER_RTX4060TI,), capacity=(0,),
                   backhaul_bits_per_s=(1e9,))
    with pytest.raises(ValueError):
        ServerTier(servers=(SERVER_RTX4060TI,), capacity=(1,),
                   backhaul_bits_per_s=(0.0,))


def test_make_server_tier_heterogeneous():
    tier = make_server_tier(4, seed=0)
    assert tier.n_servers == 4 and tier.total_capacity == 4000
    f = [s.f_max for s in tier.servers]
    assert len(set(f)) == 4, "jittered clocks must be distinct"
    arrs = tier_arrays(tier)
    assert arrs["f_max"].shape == (4,)
    assert (arrs["backhaul_bits_per_s"] > 0).all()


# --- TieredRoundContext vs flat BatchedRoundContext --------------------------


def test_s1_tier_matches_flat_batched_context():
    """A 1-server tier is exactly the paper's single-server problem: the
    tiered grid must reproduce batched_card's decisions bit-for-bit (the
    metric tensors carry an extra server axis, so their float32 sums may
    contract one ulp apart — decisions, not roundoff, are the contract)."""
    cfg = get_config("llama32-1b")
    sim = DEFAULT_SIM
    wl = Workload(cfg, sim.mini_batch, sim.seq_len)
    devices = EDGE_FLEET[:4]
    ch = draw_channel_matrix("normal", 3, 4, seed=2,
                             bandwidth_hz=sim.bandwidth_hz)
    tier = ServerTier(servers=(SERVER_RTX4060TI,), capacity=(4,),
                      backhaul_bits_per_s=(1e9,))
    tctx = TieredRoundContext.build(wl, devices, tier, ch, sim)
    bctx = BatchedRoundContext.build(wl, devices, SERVER_RTX4060TI, ch, sim)
    h = C.hierarchical_card(tctx)
    b = C.batched_card(bctx)
    assert (h.assignment == 0).all()
    np.testing.assert_array_equal(np.asarray(h.cuts), np.asarray(b.cuts))
    np.testing.assert_array_equal(np.asarray(h.freqs), np.asarray(b.freqs))
    np.testing.assert_array_equal(np.asarray(h.costs), np.asarray(b.costs))
    np.testing.assert_allclose(np.asarray(h.delays), np.asarray(b.delays),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(h.energies),
                               np.asarray(b.energies), rtol=1e-6)


def test_tiered_grid_shape_and_masking():
    _, _, tier, _, _, tctx = _tctx(n_devices=5, n_servers=3, rounds=2)
    assert tctx.shape == (3, 2, 5)
    grid = C.tiered_card_grid(tctx)
    assert grid.cuts.shape == (3, 2, 5)
    mask = np.zeros((3, 5), bool)
    mask[0, :2] = True
    masked = np.asarray(tctx.mask_unassigned(grid.delays, mask))
    assert np.isnan(masked[1]).all() and np.isnan(masked[0, :, 2:]).all()
    assert np.isfinite(masked[0, :, :2]).all()


def test_aggregation_delay_counts_assigned_adapters():
    _, _, tier, _, sim, tctx = _tctx(n_devices=4, n_servers=2, rounds=2)
    cuts = np.full((2, 4), 3, np.int32)
    mask = np.zeros((2, 4), bool)
    mask[0] = [True, True, False, False]
    mask[1] = [False, False, True, True]
    agg = np.asarray(tctx.aggregation_delay(mask, cuts))
    assert agg.shape == (2, 2)
    bits = float(np.asarray(tctx.adapter_bits)[3])
    for s in range(2):
        expect = 2 * bits / float(np.asarray(tctx.backhaul_bits_per_s)[s])
        np.testing.assert_allclose(agg[s], expect, rtol=1e-6)


# --- assignment --------------------------------------------------------------


def test_assign_greedy_unconstrained_is_argmin():
    rng = np.random.default_rng(0)
    cost = rng.uniform(1, 2, size=(3, 10))
    a = C.assign_devices(cost, np.array([10, 10, 10]), method="greedy")
    np.testing.assert_array_equal(a, cost.argmin(axis=0))


def test_assign_optimal_matches_exhaustive_random_instances():
    rng = np.random.default_rng(42)
    for trial in range(20):
        n_s, n_d = 2, int(rng.integers(2, 7))
        cost = rng.uniform(1, 5, size=(n_s, n_d))
        cap = rng.integers(1, n_d, size=n_s)
        while cap.sum() < n_d:
            cap[rng.integers(n_s)] += 1
        a = C.assign_devices(cost, cap, method="optimal")
        e = C.exhaustive_assignment(cost, cap)
        idx = np.arange(n_d)
        np.testing.assert_allclose(cost[a, idx].sum(), cost[e, idx].sum(),
                                   rtol=1e-12,
                                   err_msg=f"trial {trial}: {a} vs {e}")
        assert (np.bincount(a, minlength=n_s) <= cap).all()


def test_assign_capacity_respected_and_infeasible_raises():
    cost = np.ones((2, 4))
    with pytest.raises(ValueError):
        C.assign_devices(cost, np.array([1, 1]), method="greedy")
    a = C.assign_devices(cost, np.array([2, 2]), method="optimal")
    assert (np.bincount(a, minlength=2) <= 2).all()
    with pytest.raises(ValueError):
        C.assign_devices(cost, np.array([2, 2]), method="nope")


# --- hierarchical_card -------------------------------------------------------


def test_hierarchical_matches_exhaustive_small_fleets():
    """Acceptance: decisions match exhaustive assignment enumeration on
    fleets <= 8 devices x 2 servers."""
    for n_d, cap, seed in ((4, 3, 7), (6, 4, 11), (8, 5, 3)):
        _, _, tier, _, _, tctx = _tctx(n_devices=n_d, n_servers=2,
                                       capacity=cap, tier_seed=seed)
        h = C.hierarchical_card(tctx, assign="optimal")
        e = C.hierarchical_card_exhaustive(tctx)
        np.testing.assert_array_equal(h.assignment, e.assignment)
        np.testing.assert_array_equal(h.cuts, e.cuts)
        np.testing.assert_array_equal(h.freqs, e.freqs)
        np.testing.assert_array_equal(h.aggregation_s, e.aggregation_s)


def test_hierarchical_scalar_vs_batched_equivalence():
    """The float64 scalar loop (RoundContext + card per cell) and the jitted
    (S, R, D, C) grid agree on every decision."""
    wl, devices, tier, ch, sim, tctx = _tctx(n_devices=5, n_servers=2,
                                             capacity=3, rounds=3)
    hb = C.hierarchical_card(tctx, assign="optimal")
    hs = C.hierarchical_card_scalar(wl, devices, tier, ch, sim,
                                    assign="optimal")
    np.testing.assert_array_equal(hb.assignment, hs.assignment)
    np.testing.assert_array_equal(hb.cuts, hs.cuts)
    np.testing.assert_allclose(hb.freqs, hs.freqs, rtol=1e-5)
    np.testing.assert_allclose(hb.delays, hs.delays, rtol=1e-5)
    np.testing.assert_allclose(hb.energies, hs.energies, rtol=1e-4)
    np.testing.assert_allclose(hb.aggregation_s, hs.aggregation_s, rtol=1e-5)


def test_greedy_equals_optimal_with_slack_capacity():
    _, _, _, _, _, tctx = _tctx(n_devices=6, n_servers=3, capacity=6)
    g = C.hierarchical_card(tctx, assign="greedy")
    o = C.hierarchical_card(tctx, assign="optimal")
    np.testing.assert_array_equal(g.assignment, o.assignment)


def test_capacity_binds_load():
    _, _, tier, _, _, tctx = _tctx(n_devices=6, n_servers=2, capacity=3)
    h = C.hierarchical_card(tctx, assign="optimal")
    assert (h.server_load <= 3).all() and h.server_load.sum() == 6


# --- simulate_hierarchical_fleet --------------------------------------------


def test_simulate_hierarchical_fleet_round_times():
    cfg = get_config("llama32-1b")
    fleet = make_heterogeneous_fleet(12, seed=3)
    tier = make_server_tier(3, capacity=6, seed=2)
    log = simulate_hierarchical_fleet(cfg, tier=tier, rounds=4,
                                      devices=fleet, seed=5)
    assert log.round_s.shape == (4,)
    assert log.server_round_s.shape == (3, 4)
    # the fleet round closes with the slowest server (incl. backhaul push)
    np.testing.assert_allclose(log.round_s, log.server_round_s.max(axis=0))
    assert np.isfinite(log.mean_round_s())
    assert log.decision.server_load.sum() == 12
    # more servers can only help (or tie) the mean round time
    tier1 = make_server_tier(1, capacity=12, seed=2)
    log1 = simulate_hierarchical_fleet(cfg, tier=tier1, rounds=4,
                                       devices=fleet, seed=5)
    assert log.mean_round_s() <= log1.mean_round_s() * 1.05
