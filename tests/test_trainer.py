"""Trainer: loop, eval, checkpoint save/restore-and-resume determinism."""
import os

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data import make_fleet_datasets
from repro.launch.trainer import Trainer, TrainerConfig
from repro.models import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-0.6b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ds = make_fleet_datasets(cfg, 1, vocab=cfg.vocab_size, seed=0)[0]
    return cfg, params, ds


def test_trainer_runs_and_logs(setup, tmp_path):
    cfg, params, ds = setup
    tcfg = TrainerConfig(steps=12, batch=4, seq_len=32, eval_every=6,
                         checkpoint_every=6,
                         checkpoint_dir=str(tmp_path),
                         log_path=str(tmp_path / "log.jsonl"))
    tr = Trainer(cfg, params["frozen"], params["lora"], tcfg)
    out = tr.train(lambda: ds.minibatch(4, 32),
                   eval_batches=[ds.minibatch(4, 32)])
    assert out["final_loss"] is not None
    kinds = {m["kind"] for m in out["metrics"]}
    assert kinds == {"train", "eval"}
    assert os.path.exists(tmp_path / "trainer.npz")
    assert os.path.exists(tmp_path / "log.jsonl")


def test_trainer_restore_resumes(setup, tmp_path):
    cfg, params, ds = setup
    tcfg = TrainerConfig(steps=6, batch=4, seq_len=32, eval_every=0,
                         checkpoint_every=3, checkpoint_dir=str(tmp_path))
    tr1 = Trainer(cfg, params["frozen"], params["lora"], tcfg)
    tr1.train(lambda: ds.minibatch(4, 32))
    assert tr1.step == 6

    tr2 = Trainer(cfg, params["frozen"], params["lora"], tcfg)
    assert tr2.restore()
    assert tr2.step == 6
    # restored params identical to the saved state
    for a, b in zip(jax.tree_util.tree_leaves(tr1.lora),
                    jax.tree_util.tree_leaves(tr2.lora), strict=True):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # and training continues past the restored step
    tr2.tcfg.steps = 9
    tr2.train(lambda: ds.minibatch(4, 32))
    assert tr2.step == 9
