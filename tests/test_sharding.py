"""Sharding rules: per-arch param specs on the production meshes
(AbstractMesh — no devices needed, pure divisibility logic)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ARCH_IDS, get_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_abstract_mesh
from repro.models import model as M

MESH_1POD = make_abstract_mesh((16, 16), ("data", "model"))
MESH_2POD = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _axis_product(mesh, axis):
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


@pytest.mark.parametrize("mesh", [MESH_1POD, MESH_2POD],
                         ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible(arch, mesh):
    """Every sharded dim must be exactly divisible by its axis product."""
    cfg = get_config(arch)
    avals = M.abstract_params(cfg)
    specs = shd.param_specs(cfg, avals, mesh)
    flat_a, _ = jax.tree_util.tree_flatten(avals)
    flat_s, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_a) == len(flat_s)
    for aval, spec in zip(flat_a, flat_s, strict=True):
        assert len(spec) <= len(aval.shape)
        for dim, axis in enumerate(spec):
            if axis is None:
                continue
            k = _axis_product(mesh, axis)
            assert aval.shape[dim] % k == 0, \
                f"{arch}: shape {aval.shape} dim {dim} not divisible by {k}"


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "kimi-k2-1t-a32b",
                                  "internvl2-26b"])
def test_big_models_fit_per_chip(arch):
    """Frozen weights per chip (after sharding) must fit 16 GB HBM."""
    cfg = get_config(arch)
    avals = M.abstract_params(cfg)
    specs = shd.param_specs(cfg, avals, MESH_2POD)
    total = 0
    flat_a, _ = jax.tree_util.tree_flatten(avals["frozen"])
    flat_s, _ = jax.tree_util.tree_flatten(
        specs["frozen"], is_leaf=lambda x: isinstance(x, P))
    for aval, spec in zip(flat_a, flat_s, strict=True):
        shards = 1
        for axis in spec:
            shards *= _axis_product(MESH_2POD, axis)
        total += aval.size * aval.dtype.itemsize / shards
    assert total < 12e9, f"{arch}: {total / 1e9:.1f} GB of weights per chip"


def test_moe_layouts_match_strategy():
    from repro.models.moe_shard_map import strategy_for_mesh
    kimi = get_config("kimi-k2-1t-a32b")
    granite = get_config("granite-moe-3b-a800m")
    assert strategy_for_mesh(kimi, MESH_1POD) == "ep_a2a"
    assert strategy_for_mesh(kimi, MESH_2POD) == "ep_a2a"
    # 40 experts don't divide 16/32 -> replicated (weights ~3 GB)
    assert strategy_for_mesh(granite, MESH_1POD) == "replicated"
    specs = shd.param_specs(granite, M.abstract_params(granite), MESH_1POD)
    moe_specs = specs["frozen"]["layers"]["moe"]
    assert moe_specs["w_gate"] == P(None, None, None, None)


def test_vocab_padding_shards():
    """Odd vocabs pad to 256-multiples => vocab dim shards over 16."""
    for arch in ("internvl2-26b", "hymba-1.5b", "mamba2-370m",
                 "granite-moe-3b-a800m"):
        cfg = get_config(arch)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        avals = M.abstract_params(cfg)
        specs = shd.param_specs(cfg, avals, MESH_1POD)
        embed_spec = specs["frozen"]["embed"]
        assert embed_spec[0] == "model", f"{arch}: embed vocab not sharded"


def test_batch_specs_guard_small_batch():
    s = shd.batch_specs_for(get_config("qwen3-4b"), MESH_1POD, "decode",
                            global_batch=1)
    assert s["tokens"] == P(None, None)
    s2 = shd.batch_specs_for(get_config("qwen3-4b"), MESH_1POD, "train",
                             global_batch=256)
    assert s2["tokens"][0] in ("data", ("data",))


def test_cut_batch_specs_are_smashed():
    s = shd.batch_specs_for(get_config("qwen3-0.6b"), MESH_1POD, "train",
                            global_batch=256, cut=14)
    assert set(s) == {"smashed", "labels"}
