"""Mamba2 SSD properties: chunk-size invariance, decode==scan, decay limits."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.mamba import ssd_chunked


def _rand(seed, *shape, scale=0.3):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


@settings(max_examples=10, deadline=None)
@given(l=st.integers(8, 80), c1=st.sampled_from([8, 16, 32]),
       c2=st.sampled_from([8, 16, 32]))
def test_chunk_size_invariance(l, c1, c2):
    """SSD output must not depend on the chunking."""
    b, nh, hp, ns = 1, 2, 8, 12
    xt = _rand(0, b, l, nh, hp)
    a = -jnp.abs(_rand(1, b, l, nh, scale=0.1))
    B = _rand(2, b, l, ns)
    C = _rand(3, b, l, ns)
    y1, h1 = ssd_chunked(xt, a, B, C, c1)
    y2, h2 = ssd_chunked(xt, a, B, C, c2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-5)


def test_ssd_equals_naive_recurrence():
    """Chunked scan == the literal state-space recurrence."""
    b, l, nh, hp, ns, chunk = 1, 40, 2, 4, 6, 16
    xt = _rand(4, b, l, nh, hp)
    a = -jnp.abs(_rand(5, b, l, nh, scale=0.2))
    B = _rand(6, b, l, ns)
    C = _rand(7, b, l, ns)
    y, h_final = ssd_chunked(xt, a, B, C, chunk)

    h = np.zeros((b, nh, hp, ns))
    ys = []
    xt_n, a_n = np.asarray(xt), np.asarray(a)
    B_n, C_n = np.asarray(B), np.asarray(C)
    for t in range(l):
        h = h * np.exp(a_n[:, t])[:, :, None, None] \
            + xt_n[:, t][:, :, :, None] * B_n[:, t][:, None, None, :]
        ys.append(np.einsum("bhpn,bn->bhp", h, C_n[:, t]))
    naive = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), naive, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_final), h, atol=2e-5)


def test_zero_decay_is_cumulative_sum():
    """a == 0 (no decay): the state is a running sum of B-weighted inputs."""
    b, l, nh, hp, ns = 1, 24, 1, 2, 3
    xt = _rand(8, b, l, nh, hp)
    a = jnp.zeros((b, l, nh))
    B = jnp.ones((b, l, ns))
    C = jnp.ones((b, l, ns))
    y, h = ssd_chunked(xt, a, B, C, 8)
    # y_t = C . sum_{j<=t} B x_j = ns * cumsum(x)_t
    expect = ns * np.cumsum(np.asarray(xt), axis=1)
    np.testing.assert_allclose(np.asarray(y), expect, atol=1e-5)


def test_strong_decay_forgets():
    """Very negative a: y_t ~ contribution of x_t only."""
    b, l, nh, hp, ns = 1, 16, 1, 2, 3
    xt = _rand(9, b, l, nh, hp)
    a = jnp.full((b, l, nh), -50.0)
    B = jnp.ones((b, l, ns))
    C = jnp.ones((b, l, ns))
    y, _ = ssd_chunked(xt, a, B, C, 8)
    expect = ns * np.asarray(xt)
    np.testing.assert_allclose(np.asarray(y), expect, atol=1e-4)
