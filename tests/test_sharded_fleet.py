"""Sharded fleet sweeps: ``simulate_fleet(..., mesh=...)`` must be
bit-identical to the single-host vectorized engine — sharding the devices
axis changes data placement, never values.

The in-process tests use a 1-shard mesh (the test session pins one CPU
device); multi-shard meshes need ``--xla_force_host_platform_device_count``
set before jax initializes, so those run in a subprocess (same pattern as
``test_system.py``).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.scheduler import simulate_fleet
from repro.core.hardware import make_heterogeneous_fleet
from repro.launch.mesh import make_fleet_mesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

LOG_FIELDS = ("cuts", "freqs", "delays", "energies",
              "d_device", "d_uplink", "d_server", "d_downlink")


def _assert_identical(a, b):
    for f in LOG_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"field {f} drifted")


@pytest.mark.parametrize("policy", ["card", "server_only", "random"])
def test_one_shard_mesh_bit_identical(policy):
    cfg = get_config("llama32-1b")
    fleet = make_heterogeneous_fleet(32, seed=3)
    a = simulate_fleet(cfg, policy=policy, rounds=3, devices=fleet, seed=5)
    b = simulate_fleet(cfg, policy=policy, rounds=3, devices=fleet, seed=5,
                       mesh=make_fleet_mesh(1))
    _assert_identical(a, b)


def test_one_shard_mesh_1k_devices_bit_identical():
    """Acceptance: sharded == single-host at 1k devices."""
    cfg = get_config("llama32-1b")
    fleet = make_heterogeneous_fleet(1000, seed=3)
    a = simulate_fleet(cfg, policy="card", rounds=2, devices=fleet, seed=5)
    b = simulate_fleet(cfg, policy="card", rounds=2, devices=fleet, seed=5,
                       mesh=make_fleet_mesh(1))
    _assert_identical(a, b)


def test_mesh_requires_vectorized_engine():
    cfg = get_config("llama32-1b")
    with pytest.raises(ValueError):
        simulate_fleet(cfg, rounds=1, engine="scalar",
                       mesh=make_fleet_mesh(1))


def test_pad_lanes_trimmed():
    """5 devices on a 1-shard mesh still pads cleanly (pad=0) and ragged
    fleets never leak pad lanes into the log."""
    cfg = get_config("llama32-1b")
    fleet = make_heterogeneous_fleet(5, seed=1)
    log = simulate_fleet(cfg, policy="card", rounds=2, devices=fleet,
                         seed=2, mesh=make_fleet_mesh(1))
    assert log.delays.shape == (2, 5)
    assert np.isfinite(log.delays).all()


@pytest.mark.slow
def test_multi_shard_subprocess_bit_identical():
    """Acceptance: meshes of 1, 2, 4 shards at 1k devices, all bit-identical
    to the unsharded engine — including a ragged fleet that needs padding."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        from repro.configs.base import get_config
        from repro.core.scheduler import simulate_fleet
        from repro.core.hardware import make_heterogeneous_fleet
        from repro.launch.mesh import make_fleet_mesh

        fields = ("cuts", "freqs", "delays", "energies", "d_device",
                  "d_uplink", "d_server", "d_downlink")
        cfg = get_config("llama32-1b")
        fleet = make_heterogeneous_fleet(1000, seed=3)
        a = simulate_fleet(cfg, policy="card", rounds=2, devices=fleet,
                           seed=5)
        for n in (1, 2, 4):
            b = simulate_fleet(cfg, policy="card", rounds=2, devices=fleet,
                               seed=5, mesh=make_fleet_mesh(n))
            assert all(np.array_equal(getattr(a, f), getattr(b, f))
                       for f in fields), f"{n} shards drifted"
        # ragged: 10 devices on 4 shards pads 2 dummy lanes
        fleet10 = make_heterogeneous_fleet(10, seed=9)
        a10 = simulate_fleet(cfg, policy="card", rounds=2, devices=fleet10,
                             seed=1)
        b10 = simulate_fleet(cfg, policy="card", rounds=2, devices=fleet10,
                             seed=1, mesh=make_fleet_mesh(4))
        assert all(np.array_equal(getattr(a10, f), getattr(b10, f))
                   for f in fields), "ragged padding drifted"
        print("SHARDED-OK")
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    timeout_s = 560.0
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout_s,
                       env=env)
    assert "SHARDED-OK" in r.stdout, r.stderr[-2000:]
