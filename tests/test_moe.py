"""MoE dispatch invariants (property-based) + shard_map strategy selection."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models import moe as moe_mod
from repro.models.moe import (_capacity, group_capacity, moe_forward,
                              ranks_within_groups)


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 300), g=st.integers(1, 16), seed=st.integers(0, 99))
def test_ranks_within_groups_properties(n, g, seed):
    rng = np.random.default_rng(seed)
    groups = jnp.asarray(rng.integers(0, g, n), jnp.int32)
    ranks = np.asarray(ranks_within_groups(groups, g))
    groups_np = np.asarray(groups)
    for gid in range(g):
        r = ranks[groups_np == gid]
        # ranks within each group are exactly 0..count-1
        # splint: ignore[trace-safety] -- r is a host numpy array, no sync
        assert sorted(r.tolist()) == list(range(len(r)))
        # and assigned in original order (stable)
        assert (np.diff(r) > 0).all() if len(r) > 1 else True


@settings(max_examples=30, deadline=None)
@given(tokens=st.integers(1, 4096))
def test_capacity_bounds(tokens):
    cfg = get_config("kimi-k2-1t-a32b")
    cap = _capacity(tokens, cfg)
    assert cap >= 8 and cap % 8 == 0
    assert cap >= tokens * cfg.top_k / cfg.n_experts  # >= expected load
    gc = group_capacity(tokens, 16, 1.25)
    assert gc >= tokens / 16


def test_moe_output_is_convex_combination_scale():
    """With all experts identical, the MoE must reduce to a single expert's
    output regardless of routing (gates sum to 1)."""
    cfg = dataclasses.replace(get_config("granite-moe-3b-a800m").reduced(),
                              n_experts=4, top_k=2, capacity_factor=4.0)
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, cfg, jnp.float32)
    one = jax.tree_util.tree_map(lambda x: x, p)
    # make every expert identical to expert 0
    for w in ("w_gate", "w_up", "w_down"):
        one[w] = jnp.broadcast_to(one[w][:1], one[w].shape)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.3
    out, _ = moe_forward(one, None, x, cfg)

    # reference: dense single-expert MLP
    from repro.models.common import silu
    xf = x.reshape(-1, cfg.d_model)
    h = silu(xf @ one["w_gate"][0]) * (xf @ one["w_up"][0])
    ref = (h @ one["w_down"][0]).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_moe_aux_loss_balanced_router_is_minimal():
    """Uniform router => aux ~= coef (the Switch lower bound E*(1/E)*(1/E)*E)."""
    cfg = dataclasses.replace(get_config("granite-moe-3b-a800m").reduced(),
                              n_experts=4, top_k=1, capacity_factor=8.0)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    p["router"] = jnp.zeros_like(p["router"])  # perfectly uniform
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 32, cfg.d_model))
    _, aux = moe_forward(p, None, x, cfg)
    # me = 1/E each; ce depends on tie-broken top-1 but sum(me*ce)=1/E
    assert float(aux) == pytest.approx(cfg.router_aux_coef, rel=0.1)


def test_strategy_selection_no_mesh_is_none():
    from repro.models.moe_shard_map import select_strategy
    assert select_strategy(get_config("kimi-k2-1t-a32b")) is None


def test_dropless_reduced_configs():
    """reduced() MoE configs must be dropless (cf = E/k)."""
    for arch in ("granite-moe-3b-a800m", "kimi-k2-1t-a32b"):
        r = get_config(arch).reduced()
        assert r.capacity_factor == pytest.approx(r.n_experts / r.top_k)
