"""Hierarchical multi-server CARD at fleet scale: delay/energy vs the
number of edge servers x fleet size.

For each (servers, devices) grid point the sweep runs one full hierarchical
round — the jitted (S, R, D, C) tiered grid, the capacity-constrained
device->server assignment, and the per-server backhaul aggregation — and
reports mean per-device delay/energy, the fleet round time (slowest server
including its backhaul push), and server load imbalance. One server is the
paper's single-server baseline, so the sweep is the scaling story the
ROADMAP's top open item asks for: where a server tier buys round time.

The gated numbers are the warm wall-clock of the jitted tiered grid +
assignment at fixed shapes (compile excluded), one per tier size.

    PYTHONPATH=src python benchmarks/hierarchy_bench.py [--smoke] \
        [--json BENCH_hierarchy.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict

from repro.configs.base import get_config
from repro.core.hardware import make_heterogeneous_fleet, make_server_tier
from repro.core.scheduler import simulate_hierarchical_fleet

SCHEMA = "bench-hierarchy/v1"


def run(*, fleet_sizes=(100, 1000), tier_sizes=(1, 2, 4, 8),
        rounds: int = 5, seed: int = 0) -> Dict:
    cfg = get_config("llama32-1b")
    out: Dict = {"arch": "llama32-1b", "rounds": rounds, "sweep": []}
    gates: Dict[str, float] = {}
    for n_dev in fleet_sizes:
        fleet = make_heterogeneous_fleet(n_dev, seed=seed)
        for n_srv in tier_sizes:
            tier = make_server_tier(n_srv, capacity=-(-n_dev // n_srv),
                                    seed=seed + n_srv)
            kw = dict(tier=tier, rounds=rounds, devices=fleet, seed=seed)
            simulate_hierarchical_fleet(cfg, **kw)     # warm the jitted grid
            t0 = time.perf_counter()
            log = simulate_hierarchical_fleet(cfg, **kw)
            wall_s = time.perf_counter() - t0
            load = log.decision.server_load
            out["sweep"].append({
                "servers": n_srv, "devices": n_dev, "wall_s": wall_s,
                "mean_delay_s": log.mean_delay(),
                "mean_energy_j": log.mean_energy(),
                "mean_round_s": log.mean_round_s(),
                "mean_aggregation_s": float(
                    log.decision.aggregation_s.mean()),
                "load_imbalance": float(load.max() / max(1, load.min())),
            })
            if n_dev == max(fleet_sizes):
                gates[f"hierarchical_card_round_s_{n_srv}srv_{n_dev}dev"] \
                    = wall_s
    out["gates"] = gates
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid, just prove the path runs")
    ap.add_argument("--json", metavar="PATH",
                    help="write the BENCH_hierarchy.json payload here")
    args = ap.parse_args()
    if args.smoke:
        res = run(fleet_sizes=(50, 100), tier_sizes=(1, 2), rounds=3)
    else:
        res = run()
    res["schema"] = SCHEMA
    res["mode"] = "smoke" if args.smoke else "full"
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    print("servers,devices,mean_round_s,mean_delay_s,mean_energy_j,"
          "load_imbalance")
    for row in res["sweep"]:
        print(f"{row['servers']},{row['devices']},{row['mean_round_s']:.3f},"
              f"{row['mean_delay_s']:.3f},{row['mean_energy_j']:.3f},"
              f"{row['load_imbalance']:.2f}")


if __name__ == "__main__":
    main()
