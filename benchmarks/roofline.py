"""§Roofline: render the per-(arch x shape x mesh) roofline table from the
dry-run JSONL (results/dryrun.jsonl), with dominant-term classification and
the MODEL_FLOPS / HLO_FLOPS usefulness ratio."""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun.jsonl")


def load(path: str = DEFAULT_PATH) -> List[Dict]:
    recs: Dict = {}
    if not os.path.exists(path):
        return []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            # keep the LAST record per combo (re-runs supersede)
            recs[(r["arch"], r["shape"], r["mesh"], r.get("cut", 0))] = r
    return list(recs.values())


def table(recs: List[Dict], mesh: Optional[str] = "16x16") -> str:
    rows = []
    hdr = (f"{'arch':<22} {'shape':<12} {'mesh':<8} {'comp_s':>9} "
           f"{'mem_s':>9} {'coll_s':>9} {'dominant':>10} {'useful':>7} fits")
    rows.append(hdr)
    rows.append("-" * len(hdr))
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        if mesh and r["mesh"] != mesh:
            continue
        if not r.get("ok"):
            rows.append(f"{r['arch']:<22} {r['shape']:<12} {r['mesh']:<8} "
                        f"FAILED: {r.get('error', '?')[:60]}")
            continue
        roof = r["roofline"]
        temp = (r.get("memory") or {}).get("temp_bytes") or 0
        arg = (r.get("memory") or {}).get("argument_bytes") or 0
        fits = "Y" if (temp + arg) <= 16e9 else f"N({(temp + arg) / 1e9:.0f}G)"
        ratio = r.get("useful_flops_ratio")
        rows.append(
            f"{r['arch']:<22} {r['shape']:<12} {r['mesh']:<8} "
            f"{roof['compute_s']:>9.4g} {roof['memory_s']:>9.4g} "
            f"{roof['collective_s']:>9.4g} {roof['dominant']:>10} "
            f"{ratio if ratio is None else round(ratio, 3):>7} {fits}")
    return "\n".join(rows)


def summary(recs: List[Dict]) -> Dict:
    ok = [r for r in recs if r.get("ok")]
    doms = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(
            r["roofline"]["dominant"], 0) + 1
    return {"total": len(recs), "ok": len(ok),
            "failed": len(recs) - len(ok), "dominant_terms": doms}


def main() -> None:
    recs = load()
    if not recs:
        print("no dry-run records; run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all "
              "--mesh both --out results/dryrun.jsonl")
        return
    print(table(recs, mesh="16x16"))
    print()
    print(table(recs, mesh="2x16x16"))
    print()
    print(json.dumps(summary(recs)))


if __name__ == "__main__":
    main()
