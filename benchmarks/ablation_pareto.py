"""Ablation: the delay/energy Pareto frontier traced by the weight w in
Eq. 12, plus the static-cut and random-cut baselines the paper argues
against. Shows that the paper's headline operating point (−70.8 % delay,
−53.1 % energy) lies on CARD's achievable frontier."""
from __future__ import annotations

from typing import Dict, List


from repro.configs.base import get_config
from repro.core.hardware import SimParams
from repro.core.scheduler import simulate_fleet


def run(rounds: int = 15, seed: int = 0) -> Dict:
    cfg = get_config("llama32-1b")
    frontier: List[Dict] = []
    for w in (0.05, 0.2, 0.4, 0.6, 0.8, 0.9, 0.95):
        sim = SimParams(w=w)
        card = simulate_fleet(cfg, policy="card", rounds=rounds, seed=seed,
                              sim=sim)
        dev = simulate_fleet(cfg, policy="device_only", rounds=rounds,
                             seed=seed, sim=sim)
        srv = simulate_fleet(cfg, policy="server_only", rounds=rounds,
                             seed=seed, sim=sim)
        frontier.append({
            "w": w,
            "delay_reduction": 1 - card.mean_delay() / dev.mean_delay(),
            "energy_reduction": 1 - card.mean_energy() / srv.mean_energy(),
            "mean_freq_ghz": float(card.freqs.mean() / 1e9),
        })
    # static/random baselines at the paper's w
    sim = SimParams(w=0.2)
    extras = {}
    for policy, kw in (("static_mid", {"policy": "static", "static_cut": 16}),
                       ("random", {"policy": "random"})):
        log = simulate_fleet(cfg, rounds=rounds, seed=seed, sim=sim, **kw)
        extras[policy] = {"delay_s": log.mean_delay(),
                          "energy_j": log.mean_energy()}
    card = simulate_fleet(cfg, policy="card", rounds=rounds, seed=seed,
                          sim=sim)
    extras["card"] = {"delay_s": card.mean_delay(),
                      "energy_j": card.mean_energy()}
    # CARD dominates static/random on the scalarized cost by construction;
    # verify it also weakly dominates on at least one raw axis
    dominated = all(
        extras["card"]["delay_s"] <= extras[p]["delay_s"] + 1e-9
        or extras["card"]["energy_j"] <= extras[p]["energy_j"] + 1e-9
        for p in ("static_mid", "random"))
    return {"frontier": frontier, "baselines": extras,
            "card_dominates": bool(dominated),
            "paper_point": {"delay_reduction": 0.708,
                            "energy_reduction": 0.531}}


def main() -> None:
    import json
    print(json.dumps(run(), indent=2))


if __name__ == "__main__":
    main()
