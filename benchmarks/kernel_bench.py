"""Kernel micro-benchmarks + the measured-cost calibration pipeline.

Two layers:

  * the legacy ``bench_*`` functions — wall time of the Pallas kernels
    (interpret mode on CPU — correctness-path timing) vs their pure-jnp
    oracles, plus the analytic TPU-v5e VMEM/roofline numbers each kernel
    is designed against;
  * ``run()`` — the calibration pipeline: timing probes over the kernel
    ladder (``measured_cost.probe_kernels``), the roofline fit, and one
    calibrated ``LatencyTable`` per architecture config, emitted as the
    machine-readable ``BENCH_kernels.json`` the CI bench-trajectory job
    commits/uploads and ``check_regression.py`` gates.

    PYTHONPATH=src python benchmarks/kernel_bench.py [--smoke] \
        [--json BENCH_kernels.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.hardware import (TPU_V5E_HBM_BW, TPU_V5E_PEAK_BF16,
                                 profile_from_throughput)
from repro.core.measured_cost import (build_latency_tables, fit_roofline,
                                      probe_kernels)

SCHEMA = "bench-kernels/v1"
DEFAULT_TABLE_BATCH = 4     # SimParams.mini_batch — the fleet workload shape
DEFAULT_TABLE_SEQ = 512     # SimParams.seq_len


def _time(fn: Callable, reps: int = 3) -> float:
    jax.block_until_ready(fn())  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        # splint: ignore[trace-safety] -- timing probe: the sync IS the point
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_lora_matmul() -> Dict:
    from repro.kernels import ops, ref
    m, k, n, r = 512, 512, 512, 16
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(keys[0], (m, k), jnp.float32)
    w = jax.random.normal(keys[1], (k, n), jnp.float32)
    a = jax.random.normal(keys[2], (k, r), jnp.float32)
    b = jax.random.normal(keys[3], (r, n), jnp.float32)
    t_kernel = _time(lambda: ops.lora_matmul(x, w, a, b, 2.0))
    t_ref = _time(lambda: ref.lora_matmul_ref(x, w, a, b, 2.0))
    flops = 2 * m * k * n + 2 * m * k * r + 2 * m * r * n
    # analytic: fused kernel avoids writing/re-reading the (m, r) intermediate
    hbm_saved = 2 * m * r * 4
    return {"name": "lora_matmul_512", "us_interpret": t_kernel,
            "us_jnp_ref": t_ref,
            "tpu_compute_bound_us": flops / TPU_V5E_PEAK_BF16 * 1e6,
            "hbm_bytes_saved_by_fusion": hbm_saved}


def bench_flash_attention() -> Dict:
    from repro.kernels import ops
    from repro.models.attention import chunked_attention
    b, s, hq, hkv, d = 1, 512, 8, 4, 64
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (b, s, hq, d), jnp.float32)
    k = jax.random.normal(keys[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(keys[2], (b, s, hkv, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    t_kernel = _time(lambda: ops.flash_attention(q, k, v, block_q=128,
                                                 block_k=128))
    t_ref = _time(lambda: chunked_attention(q, k, v, causal=True, window=0,
                                            q_positions=pos, k_positions=pos))
    score_bytes = b * hq * s * s * 4  # what flash keeps out of HBM
    return {"name": "flash_attention_512", "us_interpret": t_kernel,
            "us_jnp_chunked": t_ref,
            "hbm_bytes_saved_vs_naive": score_bytes}


def bench_ssd_scan() -> Dict:
    from repro.kernels import ops
    from repro.models.mamba import ssd_chunked
    b, l, nh, hp, ns, chunk = 1, 512, 4, 64, 64, 128
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    xt = jax.random.normal(keys[0], (b, l, nh, hp)) * 0.2
    a = -jnp.abs(jax.random.normal(keys[1], (b, l, nh))) * 0.1
    B = jax.random.normal(keys[2], (b, l, ns)) * 0.3
    C = jax.random.normal(keys[3], (b, l, ns)) * 0.3
    t_kernel = _time(lambda: ops.ssd_scan(xt, a, B, C, chunk))
    t_ref = _time(lambda: ssd_chunked(xt, a, B, C, chunk))
    return {"name": "ssd_scan_512", "us_interpret": t_kernel,
            "us_jnp_ref": t_ref,
            "vmem_tile_bytes": chunk * chunk * 4 * 2 + chunk * (hp + 2 * ns) * 4}


def bench_flash_decode() -> Dict:
    from repro.kernels import ops
    from repro.models.attention import naive_attention
    b, s, hq, hkv, d = 2, 1024, 8, 4, 64
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(keys[0], (b, 1, hq, d), jnp.float32)
    k = jax.random.normal(keys[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(keys[2], (b, s, hkv, d), jnp.float32)
    t = jnp.int32(s - 1)
    t_kernel = _time(lambda: ops.flash_decode(q, k, v, t, block_k=256))
    pos = jnp.full((b, 1), s - 1, jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(s), (b, s))
    t_ref = _time(lambda: naive_attention(q, k, v, causal=True, window=0,
                                          q_positions=pos, k_positions=kpos))
    cache_bytes = 2 * b * s * hkv * d * 2  # one HBM sweep (bf16), the bound
    return {"name": "flash_decode_1k", "us_interpret": t_kernel,
            "us_jnp_ref": t_ref,
            "tpu_bandwidth_bound_us": cache_bytes / TPU_V5E_HBM_BW * 1e6}


def run(*, smoke: bool = False, reps: int = 3) -> Dict:
    """Probe -> fit -> per-arch latency tables, as one JSON-able payload.

    ``gates`` holds the jitted hot-path times ``check_regression.py`` is
    allowed to gate on (compiled jnp probe times, keyed by kernel+shape).
    Pallas interpret-mode times are deliberately NOT gated: on CPU they
    emulate the TPU program in Python and are far too noisy.
    """
    mode = "smoke" if smoke else "full"
    probes = probe_kernels(mode=mode, reps=reps)
    fit = fit_roofline(probes)
    tables = build_latency_tables(fit, batch=DEFAULT_TABLE_BATCH,
                                  seq_len=DEFAULT_TABLE_SEQ)
    host = profile_from_throughput("bench-host", fit.ref_throughput)
    payload: Dict = {
        "schema": SCHEMA,
        "mode": mode,
        "backend": jax.default_backend(),
        "probes": [p.to_dict() for p in probes],
        "roofline_fit": fit.to_dict(),
        "host_profile": {"name": host.name, "peak_flops": host.peak_flops},
        "latency_tables": {a: t.to_dict() for a, t in tables.items()},
        "gates": {f"probe_{p.kernel}_{p.shape}_s": p.seconds for p in probes
                  if p.backend == "jnp"},
    }
    if not smoke:
        payload["kernels"] = [bench_lora_matmul(), bench_flash_attention(),
                              bench_ssd_scan(), bench_flash_decode()]
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small probe ladder only (CI bench-trajectory mode)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the BENCH_kernels.json payload here")
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()
    payload = run(smoke=args.smoke, reps=args.reps)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    fit = payload["roofline_fit"]
    print(f"roofline fit [{payload['backend']}]: "
          f"C={fit['compute_flops_per_s']:.3g} FLOP/s "
          f"B={fit['bandwidth_bytes_per_s']:.3g} B/s "
          f"overhead={fit['overhead_s'] * 1e6:.0f}us "
          f"rel_residual={fit['rel_residual']:.3f}")
    for r in payload.get("kernels", ()):
        print(r)


if __name__ == "__main__":
    main()
