"""Paper Fig. 3: optimal cut layer (a) and server frequency (b) per device
across training rounds, under the dynamic wireless channel — plus the
decision-divergence report (``run_divergence``): where kernel-measured
per-layer latencies move the optimal (cut, f) vs the paper's analytic
FLOP constants.

    PYTHONPATH=src python benchmarks/fig3_decisions.py [--divergence] \
        [--bench-json BENCH_kernels.json]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional, Sequence

import numpy as np

from repro.configs.base import get_config
from repro.core.measured_cost import (LatencyTable, RooflineFit,
                                      fit_roofline, probe_kernels)
from repro.core.scheduler import simulate_fleet


def run(rounds: int = 50, channel_state: str = "normal", seed: int = 0
        ) -> Dict:
    cfg = get_config("llama32-1b")
    log = simulate_fleet(cfg, policy="card", channel_state=channel_state,
                         rounds=rounds, seed=seed, respect_memory=False)
    out = {"rounds": rounds, "devices": log.device_names}
    cut_summary = {}
    freq_summary = {}
    for m, name in enumerate(log.device_names):
        cuts = log.cuts[:, m]
        cut_summary[name] = {
            "frac_full_offload": float((cuts == 0).mean()),     # c = 0
            "frac_full_local": float((cuts == cfg.n_layers).mean()),
            "endpoints_only": bool(np.isin(cuts, [0, cfg.n_layers]).all()),
        }
        freq_summary[name] = {
            "mean_ghz": float(log.freqs[:, m].mean() / 1e9),
            "std_ghz": float(log.freqs[:, m].std() / 1e9),
        }
    out["cuts"] = cut_summary
    out["freqs"] = freq_summary
    # paper finding 1: optimal cut is bimodal {0, I}
    out["bimodal"] = all(v["endpoints_only"] for v in cut_summary.values())
    # paper finding 2: weaker devices offload more (cut -> 0 down the fleet)
    offload = [cut_summary[n]["frac_full_offload"] for n in log.device_names]
    out["offload_monotone_with_weakness"] = bool(
        all(b >= a - 1e-9
            for a, b in zip(offload, offload[1:], strict=False)))
    return out


# ---------------------------------------------------------------------------
# Decision divergence: measured latency table vs analytic constants
# ---------------------------------------------------------------------------


def _resolve_fit(bench_json: Optional[str]) -> RooflineFit:
    """Fit from a committed BENCH_kernels.json if present, else fresh
    smoke probes on this host."""
    if bench_json and os.path.exists(bench_json):
        with open(bench_json) as f:
            payload = json.load(f)
        return RooflineFit.from_dict(payload["roofline_fit"])
    return fit_roofline(probe_kernels(mode="smoke"))


def run_divergence(rounds: int = 20, *, seed: int = 0,
                   archs: Sequence[str] = ("llama32-1b", "qwen3-4b",
                                           "granite-moe-3b-a800m"),
                   channel_states: Sequence[str] = ("good", "normal", "poor"),
                   fit: Optional[RooflineFit] = None,
                   bench_json: Optional[str] = None) -> Dict:
    """Where do measured latencies move CARD's decisions?

    For every (arch, channel state), run the same fleet/channel realizations
    through ``cost_source="analytic"`` and ``cost_source="measured"`` and
    compare the per-(round, device) (cut, f) decisions."""
    if fit is None:
        fit = _resolve_fit(bench_json)
    out: Dict = {"fit": fit.to_dict(), "cells": [], "rounds": rounds}
    moved_total = 0
    n_total = 0
    for arch in archs:
        cfg = get_config(arch)
        table = LatencyTable.from_fit(cfg, fit, batch=4, seq_len=512)
        for state in channel_states:
            kw = dict(channel_state=state, rounds=rounds, seed=seed,
                      respect_memory=False)
            a = simulate_fleet(cfg, **kw)
            m = simulate_fleet(cfg, cost_source="measured",
                               latency_table=table, **kw)
            moved = a.cuts != m.cuts
            moved_total += int(moved.sum())
            n_total += moved.size
            out["cells"].append({
                "arch": arch, "channel_state": state,
                "frac_decisions_moved": float(moved.mean()),
                "mean_cut_analytic": float(a.cuts.mean()),
                "mean_cut_measured": float(m.cuts.mean()),
                "mean_abs_cut_shift": float(np.abs(m.cuts.astype(int)
                                                   - a.cuts).mean()),
                "mean_freq_shift_ghz": float((m.freqs - a.freqs).mean()
                                             / 1e9),
                "mean_delay_ratio": float(m.delays.mean()
                                          / max(a.delays.mean(), 1e-30)),
            })
    out["frac_decisions_moved_overall"] = (moved_total / n_total
                                           if n_total else 0.0)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--divergence", action="store_true",
                    help="analytic-vs-measured decision divergence report")
    ap.add_argument("--bench-json", default="BENCH_kernels.json",
                    help="reuse the roofline fit from this payload if "
                         "present (else probe fresh)")
    ap.add_argument("--rounds", type=int, default=20)
    args = ap.parse_args()
    if args.divergence:
        print(json.dumps(run_divergence(rounds=args.rounds,
                                        bench_json=args.bench_json),
                         indent=2))
    else:
        print(json.dumps(run(), indent=2))


if __name__ == "__main__":
    main()
