"""Paper Fig. 3: optimal cut layer (a) and server frequency (b) per device
across training rounds, under the dynamic wireless channel."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.configs.base import get_config
from repro.core.scheduler import simulate_fleet


def run(rounds: int = 50, channel_state: str = "normal", seed: int = 0
        ) -> Dict:
    cfg = get_config("llama32-1b")
    log = simulate_fleet(cfg, policy="card", channel_state=channel_state,
                         rounds=rounds, seed=seed, respect_memory=False)
    out = {"rounds": rounds, "devices": log.device_names}
    cut_summary = {}
    freq_summary = {}
    for m, name in enumerate(log.device_names):
        cuts = log.cuts[:, m]
        cut_summary[name] = {
            "frac_full_offload": float((cuts == 0).mean()),     # c = 0
            "frac_full_local": float((cuts == cfg.n_layers).mean()),
            "endpoints_only": bool(np.isin(cuts, [0, cfg.n_layers]).all()),
        }
        freq_summary[name] = {
            "mean_ghz": float(log.freqs[:, m].mean() / 1e9),
            "std_ghz": float(log.freqs[:, m].std() / 1e9),
        }
    out["cuts"] = cut_summary
    out["freqs"] = freq_summary
    # paper finding 1: optimal cut is bimodal {0, I}
    out["bimodal"] = all(v["endpoints_only"] for v in cut_summary.values())
    # paper finding 2: weaker devices offload more (cut -> 0 down the fleet)
    offload = [cut_summary[n]["frac_full_offload"] for n in log.device_names]
    out["offload_monotone_with_weakness"] = bool(
        all(b >= a - 1e-9 for a, b in zip(offload, offload[1:])))
    return out


def main() -> None:
    import json
    print(json.dumps(run(), indent=2))


if __name__ == "__main__":
    main()
