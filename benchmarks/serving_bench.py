"""Multi-tenant serving throughput: RPS / TTFT / tokens-per-sec trajectory.

Sweeps the continuous-batching engine over slot counts x adapter counts
(one frozen backbone, per-request LoRA adapters gathered in-jit from an
``AdapterBank``) and reports requests-per-second, mean time-to-first-token
and decoded tokens-per-second for each point. A run that fails to drain is
a hard error — undrained stats are the silent-failure mode this bench
exists to catch. The gate is the warm jitted decode-tick per slot count
(host bookkeeping and Pallas interpret times are never gated).

    PYTHONPATH=src python benchmarks/serving_bench.py [--smoke] \
        [--json BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import model as model_lib
from repro.serving import (AdapterBank, ChannelAdmissionController, Request,
                           ServingEngine)

SCHEMA = "bench-serving/v1"
ARCH = "qwen3-0.6b"
ADAPTER_SEEDS = (0, 7, 13, 21, 42, 77, 101, 202)


def _make_requests(cfg, n: int, n_adapters: int, prompt_len: int,
                   max_new: int, seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, prompt_len,
                                        dtype=np.int32).astype(np.int32),
                    max_new=max_new, adapter_id=i % n_adapters)
            for i in range(n)]


def _time_decode_tick(cfg, frozen, bank, slots: int, max_len: int,
                      iters: int) -> float:
    """Warm wall time of ONE jitted decode tick (the hot path under load:
    every slot occupied, per-slot positions and adapter ids)."""
    eng = ServingEngine(cfg, frozen, bank, slots=slots, max_len=max_len)
    toks = jnp.ones((slots, 1), jnp.int32)
    ts = jnp.arange(1, slots + 1, dtype=jnp.int32)
    ids = jnp.arange(slots, dtype=jnp.int32) % bank.n
    stacked = eng._stacked()
    logits, cache = eng._step(eng.frozen, stacked, eng.cache, toks, ts, ids)
    jax.block_until_ready(logits)                  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        logits, cache = eng._step(eng.frozen, stacked, cache, toks, ts, ids)
    jax.block_until_ready((logits, cache))
    return (time.perf_counter() - t0) / iters


def run(*, slot_counts=(2, 4), adapter_counts=(1, 4), requests: int = 8,
        prompt_len: int = 12, max_new: int = 6, prefill_chunk: int = 4,
        max_len: int = 64, tick_iters: int = 20, seed: int = 0) -> Dict:
    cfg = get_config(ARCH).reduced()
    params = model_lib.init_params(jax.random.PRNGKey(seed), cfg)
    frozen = params["frozen"]
    n_max = max(adapter_counts)
    adapters = [model_lib.init_params(jax.random.PRNGKey(s), cfg)["lora"]
                for s in ADAPTER_SEEDS[:n_max]]

    out: Dict = {"arch": ARCH,
                 "engine": {"max_len": max_len,
                            "prefill_chunk": prefill_chunk,
                            "requests": requests,
                            "prompt_len": prompt_len,
                            "max_new": max_new},
                 "sweep": []}
    for slots in slot_counts:
        for n_adapters in adapter_counts:
            bank = AdapterBank(adapters[:n_adapters])
            eng = ServingEngine(cfg, frozen, bank, slots=slots,
                                max_len=max_len,
                                prefill_chunk=prefill_chunk)
            for req in _make_requests(cfg, requests, n_adapters,
                                      prompt_len, max_new, seed):
                eng.submit(req)
            stats = eng.run_until_drained(max_ticks=50_000)
            if not stats["drained"]:
                raise RuntimeError(
                    f"serving bench did not drain at slots={slots} "
                    f"adapters={n_adapters}: pending={stats['pending']} "
                    f"after {stats['ticks']} ticks")
            out["sweep"].append({
                "slots": slots,
                "adapters": n_adapters,
                "requests": requests,
                "completed": stats["completed"],
                "drained": stats["drained"],
                "ticks": stats["ticks"],
                "prefills": stats["prefills"],
                "tokens": stats["tokens"],
                "requests_per_s": stats["requests_per_s"],
                "tokens_per_sec": stats["tokens_per_sec"],
                "mean_ttft_s": stats["mean_ttft_s"],
                "wall_s": stats["wall_s"],
            })

    # channel-aware admission under a tight budget: contention must show up
    # in the per-tenant queueing stats (informational, not gated)
    bank = AdapterBank(adapters[:min(2, n_max)])
    ctl = ChannelAdmissionController(
        bandwidth_hz=4e4, training_reserve_frac=0.5,
        token_rate_per_s=2000.0, bits_per_token=32.0, seed=seed)
    eng = ServingEngine(cfg, frozen, bank, slots=max(slot_counts),
                        max_len=max_len, prefill_chunk=prefill_chunk,
                        admission=ctl)
    for req in _make_requests(cfg, requests, bank.n, prompt_len, max_new,
                              seed + 1):
        eng.submit(req)
    adm_stats = eng.run_until_drained(max_ticks=50_000)
    if not adm_stats["drained"]:
        raise RuntimeError("admission-controlled serving run did not drain: "
                           f"pending={adm_stats['pending']}")
    out["admission"] = adm_stats["admission"]

    bank_full = AdapterBank(adapters)
    out["gates"] = {
        f"serving_decode_tick_s_{slots}slot":
            _time_decode_tick(cfg, frozen, bank_full, slots, max_len,
                              tick_iters)
        for slots in slot_counts}
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep, just prove the path runs")
    ap.add_argument("--json", metavar="PATH",
                    help="write the BENCH_serving.json payload here")
    args = ap.parse_args()
    if args.smoke:
        res = run(slot_counts=(2, 4), adapter_counts=(1, 2), requests=6,
                  prompt_len=9, max_new=4, tick_iters=10)
    else:
        res = run(slot_counts=(2, 4, 8), adapter_counts=(1, 4, 8),
                  requests=24, prompt_len=24, max_new=12, max_len=128,
                  tick_iters=50)
    res["schema"] = SCHEMA
    res["mode"] = "smoke" if args.smoke else "full"
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    print("slots,adapters,completed,rps,mean_ttft_s,tokens_per_sec,ticks")
    for row in res["sweep"]:
        print(f"{row['slots']},{row['adapters']},{row['completed']},"
              f"{row['requests_per_s']:.2f},{row['mean_ttft_s']:.4f},"
              f"{row['tokens_per_sec']:.1f},{row['ticks']}")
    for name, val in res["gates"].items():
        print(f"gate {name}: {val * 1e3:.2f} ms")


if __name__ == "__main__":
    main()
