"""Paper Fig. 4: training delay + server energy, CARD vs Server-only vs
Device-only, across channel states. Reports the paper's two headline
numbers: -70.8% delay vs device-only, -53.1% energy vs server-only."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import get_config
from repro.core.scheduler import compare_policies


def run(rounds: int = 40, seed: int = 0) -> Dict:
    cfg = get_config("llama32-1b")
    grid = compare_policies(cfg, rounds=rounds, seed=seed)
    out: Dict = {"per_state": {}}
    for state in ("good", "normal", "poor"):
        row = {}
        for policy in ("card", "server_only", "device_only"):
            log = grid[policy][state]
            row[policy] = {"delay_s": log.mean_delay(),
                           "energy_j": log.mean_energy()}
        row["delay_reduction_vs_device_only"] = \
            1 - row["card"]["delay_s"] / row["device_only"]["delay_s"]
        row["energy_reduction_vs_server_only"] = \
            1 - row["card"]["energy_j"] / row["server_only"]["energy_j"]
        out["per_state"][state] = row
    # averaged headline (paper reports single figures)
    dr = [out["per_state"][s]["delay_reduction_vs_device_only"]
          for s in out["per_state"]]
    er = [out["per_state"][s]["energy_reduction_vs_server_only"]
          for s in out["per_state"]]
    out["avg_delay_reduction"] = sum(dr) / len(dr)
    out["avg_energy_reduction"] = sum(er) / len(er)
    out["paper_claims"] = {"delay_reduction": 0.708, "energy_reduction": 0.531}
    return out


def main() -> None:
    import json
    print(json.dumps(run(), indent=2))


if __name__ == "__main__":
    main()
