"""Paper Fig. 4: training delay + server energy, CARD vs Server-only vs
Device-only, across channel states. Reports the paper's two headline
numbers: -70.8% delay vs device-only, -53.1% energy vs server-only.

Two scenarios: the paper's 5-device Table-I fleet (``run``) and a
1000-device heterogeneous fleet (``run_fleet_scale``) that checks the
headline reductions survive at the "massive mobile devices" scale the
paper motivates — only reachable through the vectorized engine."""
from __future__ import annotations

from typing import Dict, Sequence

from repro.configs.base import get_config
from repro.core.hardware import EDGE_FLEET, make_heterogeneous_fleet
from repro.core.scheduler import compare_policies


def _reductions(grid, states: Sequence[str]) -> Dict:
    out: Dict = {"per_state": {}}
    for state in states:
        row = {}
        for policy in ("card", "server_only", "device_only"):
            log = grid[policy][state]
            row[policy] = {"delay_s": log.mean_delay(),
                           "energy_j": log.mean_energy()}
        row["delay_reduction_vs_device_only"] = \
            1 - row["card"]["delay_s"] / row["device_only"]["delay_s"]
        row["energy_reduction_vs_server_only"] = \
            1 - row["card"]["energy_j"] / row["server_only"]["energy_j"]
        out["per_state"][state] = row
    # averaged headline (paper reports single figures)
    dr = [out["per_state"][s]["delay_reduction_vs_device_only"]
          for s in out["per_state"]]
    er = [out["per_state"][s]["energy_reduction_vs_server_only"]
          for s in out["per_state"]]
    out["avg_delay_reduction"] = sum(dr) / len(dr)
    out["avg_energy_reduction"] = sum(er) / len(er)
    out["paper_claims"] = {"delay_reduction": 0.708, "energy_reduction": 0.531}
    return out


def run(rounds: int = 40, seed: int = 0) -> Dict:
    """The paper's scenario: 5 Table-I edge devices."""
    cfg = get_config("llama32-1b")
    states = ("good", "normal", "poor")
    grid = compare_policies(cfg, rounds=rounds, seed=seed,
                            channel_states=states)
    out = _reductions(grid, states)
    out["devices"] = len(EDGE_FLEET)
    return out


def run_fleet_scale(n_devices: int = 1000, rounds: int = 10,
                    seed: int = 0) -> Dict:
    """1000 heterogeneous devices, vectorized engine: do the paper's
    headline reductions hold for a massive, mixed-platform fleet?"""
    cfg = get_config("llama32-1b")
    fleet = make_heterogeneous_fleet(n_devices, seed=seed)
    states = ("good", "normal", "poor")
    grid = compare_policies(cfg, rounds=rounds, seed=seed,
                            channel_states=states, devices=fleet,
                            engine="vectorized")
    out = _reductions(grid, states)
    out["devices"] = n_devices
    return out


def main() -> None:
    import json
    print(json.dumps({"paper_fleet": run(),
                      "fleet_scale_1000": run_fleet_scale()}, indent=2))


if __name__ == "__main__":
    main()
