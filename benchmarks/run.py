"""Benchmark harness — one entry per paper table/figure + system extras.

Prints ``name,us_per_call,derived`` CSV per the harness contract:
  fig3_decisions   — Fig. 3(a)/(b): cut-layer + frequency decisions
  fig4_comparison  — Fig. 4: delay/energy vs Server-only / Device-only
  fleet_scale      — vectorized engine throughput on heterogeneous fleets
  serving_sweep    — multi-tenant LoRA serving (slots x adapters throughput)
  hierarchy_sweep  — multi-server tier round-time scaling (servers x fleet)
  card_algorithm   — Alg. 1 runtime (O(I) decisions/second)
  split_step       — one real split fine-tuning epoch (tiny model, CPU)
  kernel_*         — Pallas kernel micro-benchmarks
  roofline_table   — §Roofline summary from results/dryrun.jsonl

``--smoke`` imports every benchmark module and runs tiny versions of the
figure pipelines — the CI check that keeps them importable and runnable.
"""
from __future__ import annotations

import argparse
import importlib
import pkgutil
import time


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return (time.perf_counter() - t0) * 1e6, out


def smoke() -> None:
    """Import every benchmarks/ module, then run the figure pipelines tiny."""
    import benchmarks
    rows = []
    for info in sorted(pkgutil.iter_modules(benchmarks.__path__),
                       key=lambda i: i.name):
        if info.name == "run":
            continue
        us, _ = _timed(lambda name=info.name: importlib.import_module(
            f"benchmarks.{name}"))
        rows.append((f"import_{info.name}", us, "ok"))

    from benchmarks import fig3_decisions, fig4_comparison, fleet_scale_bench
    us, fig3 = _timed(lambda: fig3_decisions.run(rounds=2))
    rows.append(("fig3_decisions_smoke", us, f"bimodal={fig3['bimodal']}"))
    us, fig4 = _timed(lambda: fig4_comparison.run(rounds=2))
    rows.append(("fig4_comparison_smoke", us,
                 f"delay_red={fig4['avg_delay_reduction']:.3f}"))
    us, scale = _timed(lambda: fig4_comparison.run_fleet_scale(
        n_devices=50, rounds=2))
    rows.append(("fig4_fleet_scale_smoke", us,
                 f"devices=50;delay_red={scale['avg_delay_reduction']:.3f}"))
    us, fleet = _timed(lambda: fleet_scale_bench.run(
        sizes=(5,), big=100, rounds=2, big_rounds=2))
    rows.append(("fleet_scale_smoke", us,
                 f"speedup={fleet['speedup_at_largest']:.1f};"
                 f"big_dec_per_s={fleet['big_fleet']['decisions_per_s']:.0f}"))
    from benchmarks import churn_bench
    us, churn = _timed(lambda: churn_bench.run(
        devices=50, rounds=2, dropout_rates=(0.0, 0.2)))
    worst = churn["sweep"][-1]
    rows.append(("churn_smoke", us,
                 f"survivors={worst['survivor_fraction']:.2f};"
                 f"quorum_rate={worst['quorum_rate']:.2f}"))
    from benchmarks import serving_bench
    us, serving = _timed(lambda: serving_bench.run(
        slot_counts=(2, 4), adapter_counts=(1, 2), requests=4,
        prompt_len=6, max_new=3, tick_iters=3))
    busiest = serving["sweep"][-1]
    rows.append(("serving_smoke", us,
                 f"completed={busiest['completed']};"
                 f"drained={busiest['drained']};"
                 f"tok_per_s={busiest['tokens_per_sec']:.0f}"))
    from benchmarks import hierarchy_bench
    us, hier = _timed(lambda: hierarchy_bench.run(
        fleet_sizes=(20, 40), tier_sizes=(1, 2), rounds=2))
    widest = hier["sweep"][-1]
    rows.append(("hierarchy_smoke", us,
                 f"servers={widest['servers']};"
                 f"round_s={widest['mean_round_s']:.1f};"
                 f"imbalance={widest['load_imbalance']:.2f}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast importability/pipeline check for CI")
    if ap.parse_args().smoke:
        smoke()
        return
    rows = []

    # --- Fig. 3 -------------------------------------------------------------
    from benchmarks import fig3_decisions
    us, fig3 = _timed(lambda: fig3_decisions.run(rounds=40))
    rows.append(("fig3_decisions", us,
                 f"bimodal={fig3['bimodal']};"
                 f"offload_monotone={fig3['offload_monotone_with_weakness']}"))

    # --- Fig. 4 -------------------------------------------------------------
    from benchmarks import fig4_comparison
    us, fig4 = _timed(lambda: fig4_comparison.run(rounds=40))
    rows.append(("fig4_comparison", us,
                 f"delay_red={fig4['avg_delay_reduction']:.3f}(paper 0.708);"
                 f"energy_red={fig4['avg_energy_reduction']:.3f}(paper 0.531)"))

    # --- fleet scale (vectorized engine vs scalar oracle) --------------------
    from benchmarks import fleet_scale_bench
    us, fleet = _timed(lambda: fleet_scale_bench.run(
        sizes=(10, 100), big=1000, rounds=5, big_rounds=10))
    b = fleet["big_fleet"]
    rows.append(("fleet_scale", us,
                 f"speedup_100dev={fleet['speedup_at_largest']:.0f}x;"
                 f"1000dev_dec_per_s={b['decisions_per_s']:.0f};"
                 f"parallel_speedup={b['parallel_speedup']:.1f}"))

    # --- churn tolerance (dropout sweep under partial aggregation) -----------
    from benchmarks import churn_bench
    us, churn = _timed(lambda: churn_bench.run())
    worst = churn["sweep"][-1]
    rows.append(("churn_sweep", us,
                 f"dropout={worst['dropout_rate']};"
                 f"survivors={worst['survivor_fraction']:.2f};"
                 f"rounds_per_commit={worst['rounds_per_commit']:.2f}"))

    # --- multi-tenant serving (slots x adapters throughput) -------------------
    from benchmarks import serving_bench
    us, serving = _timed(lambda: serving_bench.run())
    busiest = serving["sweep"][-1]
    rows.append(("serving_sweep", us,
                 f"slots={busiest['slots']};adapters={busiest['adapters']};"
                 f"rps={busiest['requests_per_s']:.1f};"
                 f"tok_per_s={busiest['tokens_per_sec']:.0f};"
                 f"ttft_s={busiest['mean_ttft_s']:.4f}"))

    # --- hierarchical tier (servers x fleet size round-time scaling) ----------
    from benchmarks import hierarchy_bench
    us, hier = _timed(lambda: hierarchy_bench.run())
    one = next(r for r in hier["sweep"]
               if r["servers"] == 1 and r["devices"] == 1000)
    widest = max(hier["sweep"], key=lambda r: (r["devices"], r["servers"]))
    rows.append(("hierarchy_sweep", us,
                 f"servers={widest['servers']};"
                 f"round_s={widest['mean_round_s']:.1f};"
                 f"tier_speedup={one['mean_round_s'] / widest['mean_round_s']:.1f}"))

    # --- CARD runtime (Alg. 1 is O(I)) ---------------------------------------
    from repro.configs.base import get_config
    from repro.core import card as card_lib
    from repro.core.channel import WirelessChannel
    from repro.core.cost_model import RoundContext, Workload
    from repro.core.hardware import DEFAULT_SIM, EDGE_FLEET, SERVER_RTX4060TI
    cfg = get_config("llama32-1b")
    ctx = RoundContext(workload=Workload(cfg, 4, 512), device=EDGE_FLEET[0],
                       server=SERVER_RTX4060TI,
                       channel=WirelessChannel("normal").draw(),
                       sim=DEFAULT_SIM)
    n = 200
    t0 = time.perf_counter()
    for _ in range(n):
        card_lib.card(ctx)
    us = (time.perf_counter() - t0) / n * 1e6
    rows.append(("card_algorithm", us, f"decisions_per_s={1e6 / us:.0f}"))

    # --- one split training epoch (real JAX) ---------------------------------
    import jax
    import numpy as np
    from repro.core.splitting import SplitExecutor
    from repro.models import model as M
    tiny = cfg.reduced()
    params = M.init_params(jax.random.PRNGKey(0), tiny)
    ex = SplitExecutor(tiny, compress=True)
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, tiny.vocab_size, (4, 64)).astype(np.int32),
             "labels": rng.integers(0, tiny.vocab_size, (4, 64)).astype(np.int32)}
    ex.step(params["frozen"], params["lora"], batch, 1)  # compile
    t0 = time.perf_counter()
    loss, _ = ex.step(params["frozen"], params["lora"], batch, 1)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("split_step_cut1", us, f"loss={float(loss):.3f}"))

    # --- kernels --------------------------------------------------------------
    from benchmarks import kernel_bench
    for bench in (kernel_bench.bench_lora_matmul,
                  kernel_bench.bench_flash_attention,
                  kernel_bench.bench_ssd_scan,
                  kernel_bench.bench_flash_decode):
        r = bench()
        rows.append((f"kernel_{r['name']}", r["us_interpret"],
                     ";".join(f"{k}={v:.4g}" for k, v in r.items()
                              if isinstance(v, (int, float)))))

    # --- Pareto ablation (w sweep + static/random baselines) ------------------
    from benchmarks import ablation_pareto
    us, ab = _timed(lambda: ablation_pareto.run(rounds=10))
    best = min(ab["frontier"],
               key=lambda f: abs(f["energy_reduction"] - 0.531))
    rows.append(("ablation_pareto", us,
                 f"card_dominates={ab['card_dominates']};"
                 f"paper_point_nearest_w={best['w']}"))

    # --- cost-model calibration vs compiled FLOPs ------------------------------
    from benchmarks import cost_model_calibration
    rows_cal = cost_model_calibration.run()
    if rows_cal:
        dense = [r["ratio_analytic_over_compiled"] for r in rows_cal
                 if r["arch"].startswith(("qwen", "phi3", "musicgen",
                                          "internvl"))]
        rows.append(("cost_model_calibration", 0.0,
                     f"dense_ratio_min={min(dense):.2f};"
                     f"dense_ratio_max={max(dense):.2f};archs={len(rows_cal)}"))

    # --- roofline summary -------------------------------------------------------
    from benchmarks import roofline
    recs = roofline.load()
    if recs:
        s = roofline.summary(recs)
        rows.append(("roofline_table", 0.0,
                     f"ok={s['ok']}/{s['total']};doms={s['dominant_terms']}"))
    else:
        rows.append(("roofline_table", 0.0, "no_dryrun_records"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
