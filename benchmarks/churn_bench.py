"""Churn tolerance at fleet scale: delay/energy/quorum vs dropout rate.

Sweeps the fault model's dropout rate (with a fixed straggler/outage mix)
over a 1000-device heterogeneous fleet and reports what the deadline-based
partial aggregation actually delivers: surviving-mean delay and energy,
survivor fraction, the fraction of rounds that reach quorum, and the
expected number of rounds per committed round. The fault realization of the
heaviest sweep point is emitted as a JSON artifact (--artifact) so a CI
failure can be replayed bit-exactly.

    PYTHONPATH=src python benchmarks/churn_bench.py [--smoke] \
        [--json BENCH_churn.json] [--artifact fault_realization.json]
"""
from __future__ import annotations

import argparse
import json
import math
import time
from typing import Dict, Optional

from repro.configs.base import get_config
from repro.core.faults import DeadlinePolicy, FaultModel
from repro.core.hardware import make_heterogeneous_fleet
from repro.core.scheduler import FleetLog, simulate_fleet

SCHEMA = "bench-churn/v1"

STRAGGLER_PROB = 0.2
OUTAGE_PROB = 0.05
QUORUM = 0.5


def _quorum_stats(log: FleetLog, quorum: float) -> Dict:
    """Per-round commit accounting from the participation mask."""
    active = log.fault_realization.active
    survivors = log.participation.sum(axis=1)
    members = active.sum(axis=1)
    needed = [max(1, math.ceil(quorum * m)) if m else 1 for m in members]
    committed = sum(int(s >= n) for s, n in zip(survivors, needed))
    rounds = log.delays.shape[0]
    return {
        "rounds": rounds,
        "committed_rounds": committed,
        "quorum_rate": committed / rounds,
        # expected rounds of wall time per committed round (inf-free: the
        # sweep caps dropout below 1, so commits always happen eventually)
        "rounds_per_commit": rounds / committed if committed else float(
            rounds),
    }


def run(*, devices: int = 1000, rounds: int = 10, seed: int = 0,
        dropout_rates=(0.0, 0.1, 0.2, 0.4)) -> Dict:
    cfg = get_config("llama32-1b")
    fleet = make_heterogeneous_fleet(devices, seed=seed)
    deadline = DeadlinePolicy(quantile=0.9)
    out: Dict = {"devices": devices, "rounds": rounds, "quorum": QUORUM,
                 "straggler_prob": STRAGGLER_PROB,
                 "outage_prob": OUTAGE_PROB, "sweep": []}
    worst_realization = None
    t_warm = None
    for rate in dropout_rates:
        fm = FaultModel(dropout_prob=rate, straggler_prob=STRAGGLER_PROB,
                        outage_prob=OUTAGE_PROB)
        kw = dict(rounds=rounds, devices=fleet, seed=seed, fault_model=fm,
                  deadline=deadline)
        simulate_fleet(cfg, **kw)              # warm the jitted grid
        t0 = time.perf_counter()
        log = simulate_fleet(cfg, **kw)
        wall_s = time.perf_counter() - t0
        row = {"dropout_rate": rate, "wall_s": wall_s,
               "mean_delay_s": log.mean_delay(),
               "mean_energy_j": log.mean_energy(),
               "survivor_fraction": log.survivor_fraction(),
               "mean_round_close_s": float(log.round_close_s.mean())}
        row.update(_quorum_stats(log, QUORUM))
        out["sweep"].append(row)
        worst_realization = log.fault_realization
        t_warm = wall_s
    # only the warm jitted sweep is gated; per-rate walls share one compile
    out["gates"] = {f"churn_sweep_round_s_{devices}dev": t_warm}
    out["worst_case_realization"] = worst_realization.to_jsonable()
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fleet, just prove the path runs")
    ap.add_argument("--json", metavar="PATH",
                    help="write the BENCH_churn.json payload here")
    ap.add_argument("--artifact", metavar="PATH",
                    help="write the heaviest sweep point's fault "
                         "realization here (bit-exact replay)")
    args = ap.parse_args()
    if args.smoke:
        res = run(devices=100, rounds=4)
    else:
        res = run()
    res["schema"] = SCHEMA
    res["mode"] = "smoke" if args.smoke else "full"
    artifact: Optional[Dict] = res.pop("worst_case_realization")
    if args.artifact:
        with open(args.artifact, "w") as f:
            json.dump(artifact, f, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.artifact}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    print("dropout,survivors,quorum_rate,rounds_per_commit,"
          "mean_delay_s,mean_energy_j")
    for row in res["sweep"]:
        print(f"{row['dropout_rate']},{row['survivor_fraction']:.3f},"
              f"{row['quorum_rate']:.2f},{row['rounds_per_commit']:.2f},"
              f"{row['mean_delay_s']:.3f},{row['mean_energy_j']:.3f}")


if __name__ == "__main__":
    main()
