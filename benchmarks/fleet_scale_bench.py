"""Fleet-scale throughput: scalar oracle vs vectorized CARD engine.

Times ``simulate_fleet`` end-to-end (channel draws + decisions + logging)
for growing heterogeneous fleets and reports decisions/second for both
engines. The vectorized engine's jit compile is amortized with a warm-up
run — a production sweep reuses the compiled grid across rounds/policies,
so steady-state throughput is the honest number. Target: >=50x at 100
devices, and a 1000-device round must complete end-to-end.

    PYTHONPATH=src python benchmarks/fleet_scale_bench.py [--smoke] \
        [--json BENCH_fleet_scale.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict

from repro.configs.base import get_config
from repro.core.hardware import make_heterogeneous_fleet
from repro.core.scheduler import parallel_round_stats, simulate_fleet

SCHEMA = "bench-fleet-scale/v1"


def _time_engine(cfg, fleet, *, engine: str, rounds: int, seed: int,
                 warmup: bool) -> float:
    if warmup:  # same-shape warm-up: jit compiles per (rounds, devices) shape
        simulate_fleet(cfg, rounds=rounds, devices=fleet, seed=seed,
                       engine=engine)
    t0 = time.perf_counter()
    simulate_fleet(cfg, rounds=rounds, devices=fleet, seed=seed, engine=engine)
    return time.perf_counter() - t0


def run(*, sizes=(10, 100), big: int = 1000, rounds: int = 5,
        big_rounds: int = 10, seed: int = 0) -> Dict:
    cfg = get_config("llama32-1b")
    out: Dict = {"scaling": [], "speedup_at_largest": None}
    for n in sizes:
        fleet = make_heterogeneous_fleet(n, seed=seed)
        t_scalar = _time_engine(cfg, fleet, engine="scalar", rounds=rounds,
                                seed=seed, warmup=False)
        t_vec = _time_engine(cfg, fleet, engine="vectorized", rounds=rounds,
                             seed=seed, warmup=True)
        decisions = rounds * n
        row = {"devices": n, "rounds": rounds,
               "scalar_s": t_scalar, "vectorized_s": t_vec,
               "scalar_dec_per_s": decisions / t_scalar,
               "vectorized_dec_per_s": decisions / t_vec,
               "speedup": t_scalar / t_vec}
        out["scaling"].append(row)
    out["speedup_at_largest"] = out["scaling"][-1]["speedup"]

    # the 1000-device heterogeneous round the paper's "massive devices"
    # claim needs — vectorized only (the scalar loop is the point of
    # comparison above, not a thing to wait on at this scale)
    fleet = make_heterogeneous_fleet(big, seed=seed)
    simulate_fleet(cfg, rounds=big_rounds, devices=fleet, seed=seed)  # compile
    t0 = time.perf_counter()
    log = simulate_fleet(cfg, rounds=big_rounds, devices=fleet, seed=seed)
    t_big = time.perf_counter() - t0
    stats = parallel_round_stats(log)
    out["big_fleet"] = {
        "devices": big, "rounds": big_rounds, "wall_s": t_big,
        "decisions_per_s": big_rounds * big / t_big,
        "mean_delay_s": log.mean_delay(),
        "mean_energy_j": log.mean_energy(),
        "parallel_exact_s": stats["parallel_exact_s"],
        "parallel_speedup": stats["speedup_exact"],
    }
    # jitted hot-path times the CI regression gate may compare PR-over-PR
    # (scalar-oracle times are the comparison subject, not a hot path, and
    # are deliberately left out)
    out["gates"] = {
        f"batched_card_round_s_{row['devices']}dev": row["vectorized_s"]
        for row in out["scaling"]
    }
    out["gates"][f"batched_card_round_s_{big}dev_big"] = t_big
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, just prove the path runs")
    ap.add_argument("--json", metavar="PATH",
                    help="write the BENCH_fleet_scale.json payload here")
    args = ap.parse_args()
    if args.smoke:
        res = run(sizes=(5, 20), big=100, rounds=2, big_rounds=2)
    else:
        res = run()
    res["schema"] = SCHEMA
    res["mode"] = "smoke" if args.smoke else "full"
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    print("devices,rounds,scalar_s,vectorized_s,speedup")
    for row in res["scaling"]:
        print(f"{row['devices']},{row['rounds']},{row['scalar_s']:.3f},"
              f"{row['vectorized_s']:.4f},{row['speedup']:.1f}")
    b = res["big_fleet"]
    print(f"big_fleet,{b['devices']}dev x {b['rounds']}r,"
          f"{b['wall_s']:.3f}s,{b['decisions_per_s']:.0f} dec/s,"
          f"parallel_speedup={b['parallel_speedup']:.1f}")


if __name__ == "__main__":
    main()
