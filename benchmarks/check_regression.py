"""CI perf gate over the BENCH_*.json trajectory.

Validates freshly produced benchmark payloads against their schemas and
compares their ``gates`` (jitted hot-path wall times, seconds) to the
committed baseline at the repo root.  Fails (exit 1) when any gated path is
more than ``--threshold`` times slower than the baseline — by design only
*jitted* hot paths are gated (``batched_card`` round times, compiled jnp
kernel probes); Pallas interpret-mode times are never emitted as gates
because CPU interpret mode is far too noisy to gate.

    # schema validation only (fails on malformed output)
    python benchmarks/check_regression.py --validate BENCH_kernels.json ...

    # full gate: fresh outputs vs committed baseline
    python benchmarks/check_regression.py \
        --baseline-dir bench_baseline --fresh-dir . --threshold 2.0
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

# repo root on sys.path so the splint unit registry is importable when this
# runs as `python benchmarks/check_regression.py` from CI
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from tools.splint.units import check_key_units  # noqa: E402

BENCH_FILES = ("BENCH_kernels.json", "BENCH_card_calibration.json",
               "BENCH_fleet_scale.json", "BENCH_churn.json",
               "BENCH_serving.json", "BENCH_hierarchy.json")

# required top-level keys per schema tag; every payload must carry
# "schema", "mode", and a (possibly empty) "gates" dict of positive floats
REQUIRED_KEYS: Dict[str, Tuple[str, ...]] = {
    "bench-kernels/v1": ("probes", "roofline_fit", "latency_tables"),
    "bench-card-calibration/v1": ("dryrun_status", "dryrun_rows", "measured"),
    "bench-fleet-scale/v1": ("scaling", "big_fleet"),
    "bench-churn/v1": ("sweep", "devices", "quorum"),
    "bench-serving/v1": ("sweep", "arch", "engine"),
    "bench-hierarchy/v1": ("sweep", "arch", "rounds"),
}


def validate(path: str) -> List[str]:
    """Return a list of schema errors (empty = valid)."""
    errors = []
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    schema = payload.get("schema")
    if schema not in REQUIRED_KEYS:
        return [f"{path}: unknown schema {schema!r} "
                f"(expected one of {sorted(REQUIRED_KEYS)})"]
    for key in REQUIRED_KEYS[schema] + ("mode", "gates"):
        if key not in payload:
            errors.append(f"{path}: missing required key {key!r}")
    gates = payload.get("gates")
    if not isinstance(gates, dict):
        errors.append(f"{path}: 'gates' must be a dict")
    else:
        for name, val in gates.items():
            if not isinstance(val, (int, float)) or not val > 0 \
                    or val != val or val == float("inf"):
                errors.append(f"{path}: gate {name!r} must be a positive "
                              f"finite number, got {val!r}")
        # gates are wall seconds by contract: every key must carry a
        # time[s] suffix and no alias/mixed unit tokens (splint registry)
        errors += check_key_units(gates.keys(), context=path,
                                  require="time[s]")
    if schema == "bench-kernels/v1" and not errors:
        tables = payload["latency_tables"]
        if not tables:
            errors.append(f"{path}: latency_tables is empty")
        for arch, tab in tables.items():
            if tab.get("schema") != "latency-table/v1":
                errors.append(f"{path}: latency table {arch!r} has bad "
                              f"schema tag {tab.get('schema')!r}")
    if schema == "bench-card-calibration/v1" and not errors:
        if not payload["measured"].get("rows"):
            errors.append(f"{path}: measured.rows is empty — the "
                          "no-dryrun fallback must still calibrate")
    if schema == "bench-churn/v1" and not errors:
        if not payload["sweep"]:
            errors.append(f"{path}: sweep is empty")
        for row in payload["sweep"]:
            frac = row.get("survivor_fraction")
            if not isinstance(frac, (int, float)) or not 0.0 <= frac <= 1.0:
                errors.append(f"{path}: survivor_fraction {frac!r} "
                              "not in [0, 1]")
    if schema == "bench-serving/v1" and not errors:
        sweep = payload["sweep"]
        if not sweep:
            errors.append(f"{path}: sweep is empty")
        for row in sweep:
            if row.get("drained") is not True:
                errors.append(f"{path}: sweep row slots={row.get('slots')} "
                              f"adapters={row.get('adapters')} did not "
                              "drain — throughput numbers are meaningless")
            for key in ("requests_per_s", "tokens_per_sec", "mean_ttft_s"):
                val = row.get(key)
                if not isinstance(val, (int, float)) or not val > 0 \
                        or val != val or val == float("inf"):
                    errors.append(f"{path}: sweep {key} must be a positive "
                                  f"finite number, got {val!r}")
        # the point of the sweep is a slot x adapter grid: require at least
        # two distinct values along each axis
        for axis in ("slots", "adapters"):
            vals = {row.get(axis) for row in sweep}
            if len(vals) < 2:
                errors.append(f"{path}: sweep covers only {sorted(vals)} "
                              f"for {axis!r} (need >= 2 distinct values)")
    if schema == "bench-hierarchy/v1" and not errors:
        sweep = payload["sweep"]
        if not sweep:
            errors.append(f"{path}: sweep is empty")
        for row in sweep:
            for key in ("mean_round_s", "mean_delay_s", "mean_energy_j"):
                val = row.get(key)
                if not isinstance(val, (int, float)) or not val > 0 \
                        or val != val or val == float("inf"):
                    errors.append(f"{path}: sweep {key} must be a positive "
                                  f"finite number, got {val!r}")
        # the point of the sweep is a servers x fleet-size grid: require at
        # least two distinct values along each axis
        for axis in ("servers", "devices"):
            vals = {row.get(axis) for row in sweep}
            if len(vals) < 2:
                errors.append(f"{path}: sweep covers only {sorted(vals)} "
                              f"for {axis!r} (need >= 2 distinct values)")
    return errors


def compare_gates(baseline_path: str, fresh_path: str,
                  threshold: float) -> List[str]:
    """Return regression messages (empty = gate green)."""
    with open(baseline_path) as f:
        base = json.load(f).get("gates", {})
    with open(fresh_path) as f:
        fresh = json.load(f).get("gates", {})
    common = sorted(set(base) & set(fresh))
    if base and fresh and not common:
        return [f"{fresh_path}: no gate keys overlap the baseline "
                f"({sorted(base)[:3]}... vs {sorted(fresh)[:3]}...) — "
                "schema drift?"]
    failures = []
    for name in common:
        ratio = fresh[name] / base[name]
        marker = "FAIL" if ratio > threshold else "ok"
        print(f"  gate {name}: {base[name]:.6g}s -> {fresh[name]:.6g}s "
              f"({ratio:.2f}x) {marker}")
        if ratio > threshold:
            failures.append(f"{fresh_path}: {name} regressed {ratio:.2f}x "
                            f"(> {threshold:.1f}x allowed)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--validate", nargs="+", metavar="FILE",
                    help="only validate these payloads, no baseline compare")
    ap.add_argument("--baseline-dir", default=".",
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh-dir", default=".",
                    help="directory holding the freshly produced BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="fail on > threshold x slowdown of a gated path")
    args = ap.parse_args()

    errors: List[str] = []
    if args.validate:
        for path in args.validate:
            errors += validate(path)
    else:
        for name in BENCH_FILES:
            fresh = os.path.join(args.fresh_dir, name)
            base = os.path.join(args.baseline_dir, name)
            if not os.path.exists(fresh):
                errors.append(f"{fresh}: missing fresh benchmark output")
                continue
            errors += validate(fresh)
            if not os.path.exists(base):
                print(f"  {name}: no committed baseline yet — skipping "
                      "compare (first run)")
                continue
            print(f"{name}:")
            errors += compare_gates(base, fresh, args.threshold)

    if errors:
        print("\nbench gate FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    print("bench gate green")
    return 0


if __name__ == "__main__":
    sys.exit(main())
