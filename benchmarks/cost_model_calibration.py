"""Cross-layer consistency of CARD's cost model, two ways:

  * ``run()``          — the paper-side analytic model (Sec. III, eta =
    FLOPs of the fine-tuning step) vs the compiled-artifact ground truth
    (dry-run probe HLO FLOPs from ``results/dryrun.jsonl``);
  * ``run_measured()`` — the analytic model vs the *measured* cost model:
    per-arch effective eta from a kernel-calibrated ``LatencyTable``
    (``measured_cost``), reported as an inflation factor (achieved
    efficiency gap).  This path needs no dry-run records, so it is the
    non-empty exit CI smoke mode relies on when ``dryrun.jsonl`` is absent.

Emits the machine-readable ``BENCH_card_calibration.json`` consumed by the
CI bench-trajectory job:

    PYTHONPATH=src python benchmarks/cost_model_calibration.py \
        [--smoke] [--json BENCH_card_calibration.json]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.core.cost_model import Workload, resolve_compute
from repro.core.measured_cost import (RooflineFit, build_latency_tables,
                                      fit_roofline, probe_kernels)

SCHEMA = "bench-card-calibration/v1"
DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun.jsonl")


def run(path: str = DEFAULT_PATH, shape_name: str = "train_4k") -> List[Dict]:
    """Analytic eta vs compiled HLO FLOPs, one row per dry-run record."""
    shape = INPUT_SHAPES[shape_name]
    recs = {}
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok") and r["shape"] == shape_name \
                        and r["mesh"] == "16x16":
                    recs[r["arch"]] = r
    rows = []
    for arch, r in sorted(recs.items()):
        cfg = get_config(arch)
        w = Workload(cfg, shape.global_batch, shape.seq_len)
        analytic = w.total_flops()                   # eta (Eq. 8 numerator)
        compiled = r["roofline"]["flops"] * 256      # global HLO FLOPs
        rows.append({
            "arch": arch,
            "analytic_eta_pflops": analytic / 1e15,
            "compiled_pflops": compiled / 1e15,
            "ratio_analytic_over_compiled": analytic / compiled,
        })
    return rows


def run_measured(*, smoke: bool = True, batch: int = 4, seq_len: int = 512,
                 fit: Optional[RooflineFit] = None) -> Dict:
    """Analytic eta vs measured effective eta for every architecture.

    ``inflation = effective / analytic`` — how much costlier the step is on
    the fitted host roofline than the paper's peak-FLOPs accounting says
    (launch overhead + bandwidth-bound layers push it above 1)."""
    if fit is None:
        fit = fit_roofline(probe_kernels(mode="smoke" if smoke else "full"))
    tables = build_latency_tables(fit, batch=batch, seq_len=seq_len)
    rows = []
    for arch in ARCH_IDS:
        w = Workload(get_config(arch), batch, seq_len)
        analytic = resolve_compute(w, "analytic")
        measured = resolve_compute(w, "measured", tables[arch])
        rows.append({
            "arch": arch,
            "analytic_eta_gflops": analytic.total_flops() / 1e9,
            "effective_eta_gflops": measured.total_flops() / 1e9,
            "inflation": measured.total_flops() / analytic.total_flops(),
        })
    return {"fit": fit.to_dict(), "batch": batch, "seq_len": seq_len,
            "rows": rows}


def build_payload(*, smoke: bool = False, path: str = DEFAULT_PATH) -> Dict:
    dryrun_rows = run(path)
    if not dryrun_rows:
        # The old behavior silently returned an empty table here, which made
        # CI smoke "pass" while measuring nothing. Say so, loudly, and fall
        # through to the measured-vs-analytic comparison, which never needs
        # dry-run records.
        print(f"skip: no usable dry-run records at {os.path.abspath(path)} "
              "(regenerate with: PYTHONPATH=src python -m repro.launch.dryrun"
              " --all --mesh both --out results/dryrun.jsonl); emitting "
              "measured-vs-analytic calibration only")
    measured = run_measured(smoke=smoke)
    return {
        "schema": SCHEMA,
        "mode": "smoke" if smoke else "full",
        "dryrun_status": "ok" if dryrun_rows else "missing",
        "dryrun_rows": dryrun_rows,
        "measured": measured,
        # nothing here is a timed hot path; the gate dict is present (schema
        # requires it) but empty
        "gates": {},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small probe ladder for the measured comparison")
    ap.add_argument("--json", metavar="PATH",
                    help="write the BENCH_card_calibration.json payload here")
    ap.add_argument("--dryrun-path", default=DEFAULT_PATH)
    args = ap.parse_args()
    payload = build_payload(smoke=args.smoke, path=args.dryrun_path)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    for row in payload["dryrun_rows"]:
        print(f"{row['arch']:24s} eta={row['analytic_eta_pflops']:9.2f}P "
              f"hlo={row['compiled_pflops']:9.2f}P "
              f"ratio={row['ratio_analytic_over_compiled']:.3f}")
    for row in payload["measured"]["rows"]:
        print(f"{row['arch']:24s} eta={row['analytic_eta_gflops']:9.1f}G "
              f"effective={row['effective_eta_gflops']:9.1f}G "
              f"inflation={row['inflation']:.3f}")


if __name__ == "__main__":
    main()
