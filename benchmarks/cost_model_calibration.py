"""Cross-layer consistency: the paper-side analytic cost model (Sec. III,
eta = FLOPs of the fine-tuning step) vs the compiled-artifact ground truth
(dry-run probe HLO FLOPs). CARD's decisions are only as good as eta — this
table shows the analytic model tracks the compiled program within ~2x for
every architecture family."""
from __future__ import annotations

import json
import os
from typing import Dict, List

from repro.configs.base import INPUT_SHAPES, get_config
from repro.core.cost_model import Workload

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun.jsonl")


def run(path: str = DEFAULT_PATH, shape_name: str = "train_4k") -> List[Dict]:
    shape = INPUT_SHAPES[shape_name]
    recs = {}
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok") and r["shape"] == shape_name \
                        and r["mesh"] == "16x16":
                    recs[r["arch"]] = r
    rows = []
    for arch, r in sorted(recs.items()):
        cfg = get_config(arch)
        w = Workload(cfg, shape.global_batch, shape.seq_len)
        analytic = w.total_flops()                   # eta (Eq. 8 numerator)
        compiled = r["roofline"]["flops"] * 256      # global HLO FLOPs
        rows.append({
            "arch": arch,
            "analytic_eta_pflops": analytic / 1e15,
            "compiled_pflops": compiled / 1e15,
            "ratio_analytic_over_compiled": analytic / compiled,
        })
    return rows


def main() -> None:
    for row in run():
        print(f"{row['arch']:24s} eta={row['analytic_eta_pflops']:9.2f}P "
              f"hlo={row['compiled_pflops']:9.2f}P "
              f"ratio={row['ratio_analytic_over_compiled']:.3f}")


if __name__ == "__main__":
    main()
