"""Fused LoRA GEMM Pallas TPU kernel: Y = X @ W + s * (X @ A) @ B.

The LoRA hot spot of the paper's fine-tuning step. The fusion keeps the
rank-r intermediate ``X @ A`` in VMEM scratch — it never round-trips through
HBM, and the adapter correction is applied while the (bm, bn) output tile is
still resident. Block sizes are MXU-aligned (multiples of 128 on the lane
dim, 8 on sublanes).

Grid: (M/bm, N/bn, K/bk), K innermost so the f32 accumulator and the
(bm, r) running ``X @ A`` live in scratch across the K sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, xa_ref, *,
            scale: float, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...],
                            preferred_element_type=jnp.float32)
    xa_ref[...] += jnp.dot(x, a_ref[...],
                           preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        adapter = jnp.dot(xa_ref[...].astype(b_ref.dtype), b_ref[...],
                          preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * adapter).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "bk",
                                             "interpret"))
def lora_matmul(x: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array,
                scale: float = 1.0, *, bm: int = 256, bn: int = 256,
                bk: int = 512, interpret: bool = False) -> jax.Array:
    """x: (M, K); w: (K, N); a: (K, r); b: (r, N) -> (M, N) in x.dtype.

    Shapes are padded up to block multiples; r is used whole (r << bn).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and a.shape[0] == k and a.shape[1] == b.shape[0] \
        and b.shape[1] == n
    r = a.shape[1]
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    # pad to multiples
    pm, pn, pk = (-m) % bm_, (-n) % bn_, (-k) % bk_
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    if pk:
        a = jnp.pad(a, ((0, pk), (0, 0)))
    if pn:
        b = jnp.pad(b, ((0, 0), (0, pn)))
    mm, nn, kk = x.shape[0], w.shape[1], x.shape[1]
    nk = kk // bk_
    grid = (mm // bm_, nn // bn_, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk_: (i, kk_)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk_: (kk_, j)),
            pl.BlockSpec((bk_, r), lambda i, j, kk_: (kk_, 0)),
            pl.BlockSpec((r, bn_), lambda i, j, kk_: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk_: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32),
                        pltpu.VMEM((bm_, r), jnp.float32)],
        interpret=interpret,
    )(x, w, a, b)
    return out[:m, :n]


def _grouped_kernel(ids_ref, x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref,
                    xa_ref, *, scale: float, nk: int):
    """One grid cell = (request g, N block j, K block k). The adapter pair
    for request g was already block-gathered by the index maps via the
    scalar-prefetched ``ids`` — the kernel body is the single-adapter fusion
    unchanged."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[0]
    acc_ref[...] += jnp.dot(x, w_ref[...],
                            preferred_element_type=jnp.float32)
    xa_ref[...] += jnp.dot(x, a_ref[0],
                           preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        adapter = jnp.dot(xa_ref[...].astype(b_ref.dtype), b_ref[0],
                          preferred_element_type=jnp.float32)
        o_ref[0] = (acc_ref[...] + scale * adapter).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bn", "bk",
                                             "interpret"))
def lora_matmul_grouped(x: jax.Array, w: jax.Array, a: jax.Array,
                        b: jax.Array, ids: jax.Array, scale: float = 1.0, *,
                        bn: int = 256, bk: int = 512,
                        interpret: bool = False) -> jax.Array:
    """Multi-tenant fused LoRA GEMM: ``y[g] = x[g] @ W + s*(x[g] @ A[ids[g]])
    @ B[ids[g]]``.

    x: (G, M, K) per-request activations; w: (K, N) shared frozen weight;
    a: (E, K, r), b: (E, r, N) the stacked adapter bank; ids: (G,) int32
    adapter index per request. Returns (G, M, N) in x.dtype.

    Grid (G, N/bn, K/bk) with K innermost; ``ids`` rides scalar prefetch so
    the BlockSpec index maps gather each request's adapter blocks straight
    from the bank — no HBM materialization of the gathered (G, K, r) tree.
    M is the per-request token count (1 in decode, the chunk size in
    prefill) and is kept whole per grid cell, padded to the sublane size.
    """
    g, m, k = x.shape
    k2, n = w.shape
    e, ka, r = a.shape
    assert k == k2 and ka == k and b.shape == (e, r, n) and ids.shape == (g,)
    bn_, bk_ = min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % 8, (-n) % bn_, (-k) % bk_
    if pm or pk:
        x = jnp.pad(x, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    if pk:
        a = jnp.pad(a, ((0, 0), (0, pk), (0, 0)))
    if pn:
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pn)))
    mm, nn, kk = x.shape[1], w.shape[1], x.shape[2]
    nk = kk // bk_
    grid = (g, nn // bn_, nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, mm, bk_), lambda gi, j, kk_, ids_: (gi, 0, kk_)),
            pl.BlockSpec((bk_, bn_), lambda gi, j, kk_, ids_: (kk_, j)),
            pl.BlockSpec((1, bk_, r),
                         lambda gi, j, kk_, ids_: (ids_[gi], kk_, 0)),
            pl.BlockSpec((1, r, bn_),
                         lambda gi, j, kk_, ids_: (ids_[gi], 0, j)),
        ],
        out_specs=pl.BlockSpec((1, mm, bn_),
                               lambda gi, j, kk_, ids_: (gi, 0, j)),
        scratch_shapes=[pltpu.VMEM((mm, bn_), jnp.float32),
                        pltpu.VMEM((mm, r), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_grouped_kernel, scale=scale, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, mm, nn), x.dtype),
        interpret=interpret,
    )(jnp.asarray(ids, jnp.int32), x, w, a, b)
    return out[:, :m, :n]
