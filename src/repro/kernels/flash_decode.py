"""Flash-decode Pallas TPU kernel: one-token GQA attention over a KV cache.

The decode_32k/long_500k hot spot: q is (group, d) per kv-head — tiny — while
k/v sweep a 32k-slot cache from HBM. The kernel streams KV blocks through
VMEM with online softmax, exactly one HBM pass over the cache (the roofline
lower bound for decode).

Grid: (batch*kv_heads, n_kv_blocks); scratch (acc, m, l) persists across the
KV sweep. The current position ``t`` arrives via scalar prefetch and masks
cache slots: linear caches attend to slots <= t; SWA ring buffers mask by
reconstructed absolute position t - ((t - j) mod W) (models/attention.py
semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(t_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            block_k: int, nk: int, window: int, slots: int, sm_scale: float):
    ki = pl.program_id(1)
    t = t_ref[0]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                   # (group, d)
    k = k_ref[0]                                   # (block_k, d)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    j = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                (1, block_k), 1)[0]
    in_cache = j < slots                           # excludes block padding
    if window and window <= slots:                 # ring buffer
        abs_pos = t - ((t - j) % slots)
        valid = in_cache & (abs_pos >= 0) & (abs_pos <= t) \
            & (abs_pos > t - window)
    else:                                          # linear cache
        valid = in_cache & (j <= t)
        if window:
            valid &= j > t - window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = (acc_ref[...] * alpha
                    + jax.lax.dot_general(p.astype(v.dtype), v,
                                          (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, t: jax.Array, *,
                 window: int = 0, block_k: int = 512,
                 interpret: bool = False) -> jax.Array:
    """q: (BH, G, D) one query token per kv-head group;
    k, v: (BH, S, D) cache; t: scalar int32 current position.
    Returns (BH, G, D)."""
    bh, g, d = q.shape
    s = k.shape[1]
    bk = min(block_k, s)
    pad = (-s) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        # padded slots: j >= s -> for ring caches (t-j)%slots uses true slot
        # count, so mask padded region via the linear-valid check below; we
        # pass slots = s (true) and rely on abs_pos/j masks excluding j >= s
        # only when t < j. To be exact, clamp by marking them invalid:
    sp = k.shape[1]
    nk = sp // bk
    sm_scale = 1.0 / (d ** 0.5)
    t_arr = jnp.asarray(t, jnp.int32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, nk),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda b, j, t_: (b, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, t_: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, t_: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda b, j, t_: (b, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, d), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, block_k=bk, nk=nk, window=window,
                          slots=s, sm_scale=sm_scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, g, d), q.dtype),
        interpret=interpret,
    )(t_arr, q, k, v)
    return out
