"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def lora_matmul_ref(x: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array,
                    scale: float = 1.0) -> jax.Array:
    y = jnp.matmul(x, w.astype(x.dtype), preferred_element_type=jnp.float32)
    xa = jnp.matmul(x, a.astype(x.dtype), preferred_element_type=jnp.float32)
    y = y + scale * jnp.matmul(xa.astype(x.dtype), b.astype(x.dtype),
                               preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def lora_matmul_grouped_ref(x: jax.Array, w: jax.Array, a: jax.Array,
                            b: jax.Array, ids: jax.Array,
                            scale: float = 1.0) -> jax.Array:
    """x: (G, M, K); w: (K, N); a: (E, K, r); b: (E, r, N); ids: (G,)."""
    y = jnp.matmul(x, w.astype(x.dtype), preferred_element_type=jnp.float32)
    ag = a[ids].astype(x.dtype)                        # (G, K, r)
    bg = b[ids].astype(x.dtype)                        # (G, r, N)
    xa = jnp.matmul(x, ag, preferred_element_type=jnp.float32).astype(x.dtype)
    y = y + scale * jnp.matmul(xa, bg, preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q: (BH, Sq, D); k, v: (BH, Skv, D); positions = arange."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(d))
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_intra_chunk_ref(xt: jax.Array, a: jax.Array, B: jax.Array,
                        C: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for ssd_scan.ssd_intra_chunk. Shapes as the kernel."""
    b, nc, cl, nh, hp = xt.shape
    xt = xt.astype(jnp.float32)
    a = a.astype(jnp.float32)
    Bc = B.astype(jnp.float32)
    Cc = C.astype(jnp.float32)
    cum = jnp.cumsum(a, axis=2)                         # (b,nc,cl,nh)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((cl, cl), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)
    y = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, decay, xt)
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)
    st = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, dec_end, xt)
    dec = jnp.stack([jnp.exp(cum),
                     jnp.broadcast_to(jnp.exp(cum[:, :, -1:, :]),
                                      cum.shape)], axis=-1)
    return y, st, dec


def ssd_full_ref(xt: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array,
                 chunk: int) -> jax.Array:
    """End-to-end SSD oracle — delegates to the model's shared impl."""
    from repro.models.mamba import ssd_chunked
    y, _ = ssd_chunked(xt, a, B, C, chunk)
    return y
