"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs in Python per grid cell, which validates the exact TPU
program logic. On a real TPU backend ``interpret=False`` compiles to Mosaic.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd
from repro.kernels import lora_matmul as _lm
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def lora_matmul(x: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array,
                scale: float = 1.0, **block_kw) -> jax.Array:
    """Fused y = x @ W + scale*(x @ A) @ B. Leading dims of x are flattened."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _lm.lora_matmul(x2, w, a, b, scale, interpret=_interpret(), **block_kw)
    return y.reshape(*lead, w.shape[-1])


def lora_matmul_grouped(x: jax.Array, w: jax.Array, a: jax.Array,
                        b: jax.Array, ids: jax.Array, scale: float = 1.0,
                        **block_kw) -> jax.Array:
    """Multi-tenant fused LoRA: y[g] = x[g] @ W + scale*(x[g] @ A[ids[g]])
    @ B[ids[g]]. x: (G, M, K) or (G, K); a: (E, K, r); b: (E, r, N);
    ids: (G,) int32 adapter index per request row."""
    squeeze = x.ndim == 2
    if squeeze:
        x = x[:, None, :]
    y = _lm.lora_matmul_grouped(x, w, a, b, jnp.asarray(ids, jnp.int32),
                                scale, interpret=_interpret(), **block_kw)
    return y[:, 0] if squeeze else y


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_positions=None, k_positions=None,
                    **block_kw) -> jax.Array:
    """GQA-aware wrapper. q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D).

    The kernel assumes positions are 0..S-1; generalized position vectors
    (ring-buffer decode) stay on the jnp path in models/attention.py.
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    # fold (batch, kv_head, group) into the kernel's leading dim; queries of
    # one group share their KV head
    qf = (q.reshape(b, sq, hkv, group, d)
          .transpose(0, 2, 3, 1, 4)
          .reshape(b * hkv * group, sq, d))
    kf = (jnp.broadcast_to(k[:, :, :, None, :], (b, skv, hkv, group, d))
          .transpose(0, 2, 3, 1, 4)
          .reshape(b * hkv * group, skv, d))
    vf = (jnp.broadcast_to(v[:, :, :, None, :], (b, skv, hkv, group, d))
          .transpose(0, 2, 3, 1, 4)
          .reshape(b * hkv * group, skv, d))
    out = _fa.flash_attention(qf, kf, vf, causal=causal, window=window,
                              interpret=_interpret(), **block_kw)
    return (out.reshape(b, hkv, group, sq, d)
            .transpose(0, 3, 1, 2, 4)
            .reshape(b, sq, hq, d))


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 t, *, window: int = 0, **block_kw) -> jax.Array:
    """One-token GQA decode attention. q: (B, 1, Hq, D); caches:
    (B, S, Hkv, D). Returns (B, 1, Hq, D). Ring-buffer SWA caches use
    window == slots semantics (models/attention.py)."""
    b, _, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    qf = q[:, 0].reshape(b, hkv, group, d).reshape(b * hkv, group, d)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    out = _fd.flash_decode(qf, kf, vf, t, window=window,
                           interpret=_interpret(), **block_kw)
    return out.reshape(b, hkv, group, d).reshape(b, 1, hq, d)


def ssd_scan(xt: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array,
             chunk: int, h0: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Full SSD scan using the Pallas intra-chunk kernel + jnp inter-chunk
    recurrence. Same contract as models.mamba.ssd_chunked:
    xt: (B, L, nh, hp); a: (B, L, nh); B, C: (B, L, ns).
    Returns (y: (B, L, nh, hp) f32, h_final: (B, nh, hp, ns) f32).
    """
    b, l, nh, hp = xt.shape
    ns = B.shape[-1]
    pad = (-l) % chunk
    if pad:
        xt = jnp.pad(xt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    lp = xt.shape[1]
    nc = lp // chunk
    xt_c = xt.reshape(b, nc, chunk, nh, hp)
    a_c = a.reshape(b, nc, chunk, nh)
    B_c = B.reshape(b, nc, chunk, ns)
    C_c = C.reshape(b, nc, chunk, ns)

    y_diag, states, dec = _ssd.ssd_intra_chunk(
        xt_c, a_c, B_c, C_c, interpret=_interpret())
    # states: (b, nc, nh, ns, hp) -> match (b, nc, nh, hp, ns)
    states = states.transpose(0, 1, 2, 4, 3)

    if h0 is None:
        h0 = jnp.zeros((b, nh, hp, ns), jnp.float32)
    a_tot = dec[:, :, -1, :, 1]                        # (b, nc, nh) total decay

    def step(h, inp):
        at, st = inp
        return h * at[:, :, None, None] + st, h

    h_final, h_prevs = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (a_tot.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)         # (b, nc, nh, hp, ns)

    # cross-chunk correction: C_i . h_prev * exp(cum_i)
    y_off = jnp.einsum("bcin,bcihpn->bcihp", C_c.astype(jnp.float32),
                       dec[..., 0][..., None, None] * h_prevs[:, :, None])
    y = (y_diag + y_off).reshape(b, lp, nh, hp)
    return y[:, :l], h_final
