"""Mamba-2 SSD intra-chunk Pallas TPU kernel.

The SSD algorithm splits into (i) an intra-chunk quadratic part + per-chunk
final state, both embarrassingly parallel over (batch, head, chunk), and
(ii) a tiny inter-chunk linear recurrence. This kernel implements (i) with
VMEM tiling — the (chunk x chunk) decay/score matrices never leave VMEM.
The O(n_chunks) recurrence (ii) and the cross-chunk output correction stay
in jnp (they are bandwidth-trivial); see ops.ssd_scan.

Grid: (batch, n_heads, n_chunks). Per cell:
  y_diag[i] = sum_{j<=i} (C_i . B_j) * exp(cum_i - cum_j) * xt_j
  state     = sum_j exp(cum_last - cum_j) * B_j (x) xt_j
  (also emits exp(cum) and exp(cum_last - cum) decay vectors for the jnp
  cross-chunk correction)

VMEM at defaults (chunk=256, hp=64, ns=128, f32): xt 64 KiB, B/C 128 KiB,
decay/score matrices 256 KiB each — well under budget, MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, dec_ref, *,
            chunk: int):
    xt = xt_ref[0, 0].astype(jnp.float32)          # (cl, hp)
    a = a_ref[0, 0].astype(jnp.float32)            # (cl, 1)
    B = b_ref[0, 0].astype(jnp.float32)            # (cl, ns)
    C = c_ref[0, 0].astype(jnp.float32)            # (cl, ns)

    cum = jnp.cumsum(a[:, 0])                      # (cl,)
    seg = cum[:, None] - cum[None, :]              # (cl, cl)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general((scores * decay).astype(xt.dtype), xt,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # chunk-final state: sum_j exp(cum_last - cum_j) B_j (x) xt_j
    dec_end = jnp.exp(cum[-1] - cum)               # (cl,)
    bw = B * dec_end[:, None]                      # (cl, ns)
    st = jax.lax.dot_general(bw, xt, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    st_ref[0, 0] = st.astype(st_ref.dtype)         # (ns, hp)

    # decay vectors for the jnp cross-chunk correction:
    #   dec[:, 0] = exp(cum)  (applied to h_prev),  dec[:, 1] = total decay
    dec_ref[0, 0, :, 0] = jnp.exp(cum).astype(dec_ref.dtype)
    dec_ref[0, 0, :, 1] = jnp.full((chunk,), jnp.exp(cum[-1]),
                                   dec_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(xt: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array,
                    *, interpret: bool = False):
    """xt: (b, nc, cl, nh, hp) pre-multiplied by dt; a: (b, nc, cl, nh);
    B, C: (b, nc, cl, ns). Returns:
      y_diag: (b, nc, cl, nh, hp), states: (b, nc, nh, ns, hp),
      dec:    (b, nc, cl, nh, 2)  [exp(cum), total-decay]
    """
    b, nc, cl, nh, hp = xt.shape
    ns = B.shape[-1]
    # layout: head-major for the grid
    xt_h = xt.transpose(0, 3, 1, 2, 4).reshape(b * nh, nc, cl, hp)
    a_h = a.transpose(0, 3, 1, 2).reshape(b * nh, nc, cl, 1)
    B_r = jnp.broadcast_to(B[:, None], (b, nh, nc, cl, ns)).reshape(
        b * nh, nc, cl, ns)
    C_r = jnp.broadcast_to(C[:, None], (b, nh, nc, cl, ns)).reshape(
        b * nh, nc, cl, ns)

    y, st, dec = pl.pallas_call(
        functools.partial(_kernel, chunk=cl),
        grid=(b * nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, cl, hp), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, cl, 1), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, cl, ns), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, cl, ns), lambda g, c: (g, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cl, hp), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, ns, hp), lambda g, c: (g, c, 0, 0)),
            pl.BlockSpec((1, 1, cl, 2), lambda g, c: (g, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * nh, nc, cl, hp), jnp.float32),
            jax.ShapeDtypeStruct((b * nh, nc, ns, hp), jnp.float32),
            jax.ShapeDtypeStruct((b * nh, nc, cl, 2), jnp.float32),
        ],
        interpret=interpret,
    )(xt_h, a_h, B_r, C_r)

    y = y.reshape(b, nh, nc, cl, hp).transpose(0, 2, 3, 1, 4)
    st = st.reshape(b, nh, nc, ns, hp).transpose(0, 2, 1, 3, 4)
    dec = dec.reshape(b, nh, nc, cl, 2).transpose(0, 2, 3, 1, 4)
    return y, st, dec
