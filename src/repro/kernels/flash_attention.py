"""Flash attention Pallas TPU kernel (causal / sliding-window, GQA-aware
via the ops wrapper).

Online-softmax over KV blocks. Grid: (batch*kv_heads*group, nq, nk) with the
KV dimension innermost; the f32 accumulator and the running (m, l) statistics
persist in VMEM scratch across the KV sweep (TPU grid execution is
sequential). Causal/SWA masking is computed from block indices with
broadcasted iota — fully-masked KV blocks are skipped with pl.when.

Block sizes default to (block_q=512, block_k=512): q/k/v tiles of
512x128 bf16 = 128 KiB each — comfortably within the ~16 MiB VMEM budget,
MXU-aligned on both dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, window: int, block_q: int, block_k: int,
            nk: int, sm_scale: float, skv_true: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos0 = qi * block_q
    k_pos0 = ki * block_k
    # block-level skip: KV block entirely in the future (causal), entirely
    # behind the window, or entirely padding
    run = k_pos0 < skv_true
    if causal:
        run = jnp.logical_and(run, k_pos0 <= q_pos0 + block_q - 1)
    if window:
        run = jnp.logical_and(run, k_pos0 + block_k - 1 > q_pos0 - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0]                       # (block_q, d)
        k = k_ref[0]                       # (block_k, d)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (bq, bk)

        q_pos = q_pos0 + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        k_pos = k_pos0 + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = k_pos < skv_true          # mask padded keys
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * alpha
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, D); k, v: (BH, Skv, D). Positions are 0..S-1 (standard
    train/prefill). GQA head-group folding happens in ops.flash_attention.
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    bq, bk = min(block_q, sq), min(block_k, skv)
    pq, pk = (-sq) % bq, (-skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    sq_p, skv_p = q.shape[1], k.shape[1]
    nq, nk = sq_p // bq, skv_p // bk
    sm_scale = 1.0 / (d ** 0.5)

    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, window=window,
                          block_q=bq, block_k=bk, nk=nk, sm_scale=sm_scale,
                          skv_true=skv),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_p, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
