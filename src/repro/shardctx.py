"""Trace-time sharding context: lets model code place divisibility-guarded
``with_sharding_constraint``s without threading the mesh through every call.

The launcher (dryrun/train/serve) wraps tracing in ``mesh_ctx(mesh)``; model
code calls ``constrain(x, 'dp', None, 'model')`` with one tag per dim:

  'dp'    -> shard over the data-parallel axes ("pod","data") if divisible
  'model' -> shard over the tensor-parallel axis if divisible
  None    -> replicated

Outside a context (CPU tests, single device) ``constrain`` is a no-op, so
the model code is backend-agnostic.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_CTX: Optional[Dict] = None


def set_ctx(mesh) -> None:
    global _CTX
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    model = mesh.shape.get("model", 1)
    _CTX = {"dp": dp, "dp_size": dp_size, "model": model, "mesh": mesh}


def clear_ctx() -> None:
    global _CTX
    _CTX = None


@contextlib.contextmanager
def mesh_ctx(mesh):
    set_ctx(mesh)
    try:
        yield
    finally:
        clear_ctx()


def active() -> bool:
    return _CTX is not None


def axis_size(tag: str) -> int:
    """Size of the 'model' or 'dp' axis group (1 without a context)."""
    if _CTX is None:
        return 1
    return _CTX["dp_size"] if tag == "dp" else _CTX["model"]


def dp_axes() -> Tuple[str, ...]:
    return _CTX["dp"] if _CTX else ()


def mesh():
    return _CTX["mesh"] if _CTX else None


def constrain(x: jax.Array, *tags) -> jax.Array:
    """Apply a guarded sharding constraint; no-op without a context."""
    if _CTX is None:
        return x
    assert len(tags) == x.ndim, (tags, x.shape)
    spec = []
    for dim, tag in enumerate(tags):
        if tag == "dp" and _CTX["dp"] and x.shape[dim] % _CTX["dp_size"] == 0:
            spec.append(_CTX["dp"])
        elif tag == "model" and _CTX["model"] > 1 \
                and x.shape[dim] % _CTX["model"] == 0:
            spec.append("model")
        else:
            spec.append(None)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
