"""Serving steps: prefill and single-token decode (KV/SSM cache).

``serve_step`` is what the decode input shapes (decode_32k, long_500k)
lower: ONE new token against a cache of ``seq_len``. For SWA variants the
cache is a ring buffer of ``window`` slots (models/attention.py), which is
what makes long_500k feasible for attention archs.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.models.common import Params


def make_serve_step(cfg: ModelConfig, *, unroll: bool = False) -> Callable:
    def serve_step(frozen: Params, lora: Optional[Params], cache: Params,
                   inputs: jax.Array, t: jax.Array
                   ) -> Tuple[jax.Array, Params]:
        return model_lib.decode_step(frozen, lora, cache, inputs, t, cfg,
                                     unroll=unroll)

    return serve_step


def make_prefill_step(cfg: ModelConfig, *, impl: str = "chunked",
                      unroll: bool = False) -> Callable:
    def prefill_step(frozen: Params, lora: Optional[Params],
                     inputs: jax.Array) -> jax.Array:
        logits, _ = model_lib.prefill(frozen, lora, inputs, cfg, impl=impl,
                                      unroll=unroll)
        return logits

    return prefill_step


def generate(cfg: ModelConfig, frozen: Params, lora: Optional[Params],
             prompt: jax.Array, max_new: int, *, temperature: float = 0.0,
             key: Optional[jax.Array] = None) -> jax.Array:
    """Greedy/sampled autoregressive generation (CPU-scale example driver).

    prompt: (B, S0) tokens (or (B, S0, d) embeds). Returns (B, max_new)."""
    b = prompt.shape[0]
    s0 = prompt.shape[1]
    cache = model_lib.init_cache(cfg, b, s0 + max_new)
    serve_step = jax.jit(make_serve_step(cfg))

    # prefill token-by-token through the cache (exercises the decode path)
    tok = None
    for t in range(s0):
        inp = prompt[:, t:t + 1]
        logits, cache = serve_step(frozen, lora, cache, inp, jnp.int32(t))
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    if key is None:
        key = jax.random.PRNGKey(0)
    for i in range(max_new):
        out.append(tok)
        logits, cache = serve_step(frozen, lora, cache, tok,
                                   jnp.int32(s0 + i))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature
                                         ).astype(jnp.int32)[:, None]
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    return jnp.concatenate(out, axis=1)
