"""Production training loop: jitted step + periodic eval + checkpointing
with resume + JSONL metrics. The server-side counterpart of the SL protocol
for long-running pod jobs (the protocol drives rounds; the Trainer owns the
optimizer state, checkpoints and metrics stream).
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig
from repro.core.faults import ExchangeFailed, RetryPolicy, retry_call
from repro.launch.train import make_train_step
from repro.models import model as model_lib
from repro.models.common import Params
from repro.optim import Optimizer, adamw, warmup_cosine


@dataclass
class TrainerConfig:
    steps: int = 200
    batch: int = 8
    seq_len: int = 64
    lr: float = 3e-3
    warmup: int = 20
    eval_every: int = 50
    eval_batches: int = 4
    checkpoint_every: int = 100
    checkpoint_dir: Optional[str] = None
    microbatches: int = 1
    impl: str = "naive"
    remat: bool = False
    log_path: Optional[str] = None
    # failure semantics: checkpoint/metrics I/O retries with capped backoff,
    # and divergence detection at the (already synced) logging points
    io_retries: int = 3
    io_backoff_s: float = 0.05
    max_nonfinite: int = 3     # consecutive non-finite losses before abort


class TrainingDiverged(RuntimeError):
    """Loss went non-finite for ``max_nonfinite`` consecutive log points."""


class Trainer:
    """Owns (lora, opt_state); the frozen backbone is read-only."""

    def __init__(self, cfg: ModelConfig, frozen: Params, lora: Params,
                 tcfg: TrainerConfig, *,
                 optimizer: Optional[Optimizer] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.frozen = frozen
        self.lora = lora
        self.optimizer = optimizer or adamw(
            warmup_cosine(tcfg.lr, tcfg.warmup, tcfg.steps))
        self.opt_state = self.optimizer.init(lora)
        self.step = 0
        self.metrics: List[Dict] = []
        self._nonfinite_streak = 0
        self._io_policy = RetryPolicy(max_attempts=max(1, tcfg.io_retries),
                                      base_backoff_s=tcfg.io_backoff_s)
        self._train_step = jax.jit(make_train_step(
            cfg, self.optimizer, impl=tcfg.impl, remat=tcfg.remat,
            microbatches=tcfg.microbatches))
        self._eval_loss = jax.jit(
            lambda fr, lo, b: model_lib.forward_loss(
                fr, lo, b, cfg, impl=tcfg.impl, remat=False))

    # --- checkpointing ------------------------------------------------------

    def _ckpt_path(self) -> Optional[str]:
        d = self.tcfg.checkpoint_dir
        return os.path.join(d, "trainer.npz") if d else None

    def save(self) -> None:
        """Checkpoint under I/O retries; a persistently failing filesystem
        degrades to a logged warning instead of killing the run (the next
        checkpoint interval tries again)."""
        path = self._ckpt_path()
        if not path:
            return
        try:
            retry_call(
                lambda: save_checkpoint(path, {"lora": self.lora,
                                               "opt_state": self.opt_state},
                                        step=self.step),
                self._io_policy, retry_on=(OSError,), sleep=time.sleep)
        except ExchangeFailed as e:
            self._log({"kind": "warning",
                       "event": "checkpoint_failed", "error": str(e)})

    def restore(self) -> bool:
        path = self._ckpt_path()
        if not path or not os.path.exists(path):
            return False
        like = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            {"lora": self.lora, "opt_state": self.opt_state})
        tree, step = load_checkpoint(path, like)
        self.lora = tree["lora"]
        self.opt_state = tree["opt_state"]
        self.step = step
        return True

    # --- loop -----------------------------------------------------------------

    def _log(self, rec: Dict) -> None:
        rec["step"] = self.step
        rec["time"] = time.time()
        self.metrics.append(rec)
        if self.tcfg.log_path:
            try:
                retry_call(lambda: self._append_log_line(rec),
                           self._io_policy, retry_on=(OSError,),
                           sleep=time.sleep)
            except ExchangeFailed:
                # metrics stream is best-effort; in-memory copy is intact
                rec["dropped_from_stream"] = True

    def _append_log_line(self, rec: Dict) -> None:
        with open(self.tcfg.log_path, "a") as f:
            f.write(json.dumps(rec) + "\n")

    def _check_finite(self, loss: float) -> None:
        if loss == loss and abs(loss) != float("inf"):
            self._nonfinite_streak = 0
            return
        self._nonfinite_streak += 1
        self._log({"kind": "warning", "event": "nonfinite_loss",
                   "streak": self._nonfinite_streak})
        if self._nonfinite_streak >= self.tcfg.max_nonfinite:
            raise TrainingDiverged(
                f"loss non-finite at {self._nonfinite_streak} consecutive "
                f"log points (step {self.step})")

    def evaluate(self, eval_batches: List[Dict[str, Any]]) -> float:
        losses = [float(self._eval_loss(self.frozen, self.lora,
                                        {k: jnp.asarray(v)
                                         for k, v in b.items()}))
                  for b in eval_batches]
        return sum(losses) / max(len(losses), 1)

    def train(self, next_batch: Callable[[], Dict[str, Any]],
              eval_batches: Optional[List[Dict[str, Any]]] = None) -> Dict:
        t = self.tcfg
        t0 = time.time()
        while self.step < t.steps:
            batch = {k: jnp.asarray(v) for k, v in next_batch().items()}
            loss, self.lora, self.opt_state = self._train_step(
                self.frozen, self.lora, self.opt_state, batch)
            self.step += 1
            if self.step % 10 == 0 or self.step == 1:
                # splint: ignore[trace-safety] -- 1-in-10 gated metrics sync
                loss_val = float(loss)
                self._log({"kind": "train", "loss": loss_val})
                self._check_finite(loss_val)
            if eval_batches and t.eval_every \
                    and self.step % t.eval_every == 0:
                self._log({"kind": "eval",
                           "loss": self.evaluate(eval_batches)})
            if t.checkpoint_every and self.step % t.checkpoint_every == 0:
                self.save()
        self.save()
        train_losses = [m["loss"] for m in self.metrics
                        if m["kind"] == "train"]
        return {"final_loss": train_losses[-1] if train_losses else None,
                "steps_per_sec": self.step / max(time.time() - t0, 1e-9),
                "metrics": self.metrics}
