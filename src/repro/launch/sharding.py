"""Sharding rules: parameter tree -> PartitionSpec tree, per architecture.

Megatron-style tensor parallelism on the ``model`` axis, batch parallelism
on ("pod", "data"), and 2D (expert x ffn) sharding for MoE expert weights so
trillion-parameter configs fit per-chip HBM.

Every rule is divisibility-guarded: if a dim is not divisible by the axis
size the rule falls back (next candidate dim, then replication) instead of
relying on GSPMD padding — keeps the compiled collective schedule clean.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import data_axes

Params = Any


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def shard_dim_if(mesh: Mesh, shape: Tuple[int, ...], *rules) -> P:
    """rules: (dim_index, axis). Apply each rule whose dim is divisible by
    the axis size; skip otherwise."""
    spec = [None] * len(shape)
    used = set()
    for dim, axis in rules:
        if axis is None:
            continue
        size = _axis_size(mesh, axis)
        names = axis if isinstance(axis, tuple) else (axis,)
        if any(n in used for n in names):
            continue
        if size > 1 and shape[dim] % size == 0 and spec[dim] is None:
            spec[dim] = axis
            used.update(names)
    return P(*spec)


def param_specs(cfg: ModelConfig, params: Params, mesh: Mesh) -> Params:
    """PartitionSpec tree matching ``params`` structure ({"frozen","lora"})."""
    mdl = "model"
    dp = data_axes(mesh)
    moe_strategy = None
    if cfg.is_moe:
        from repro.models.moe_shard_map import strategy_for_mesh
        moe_strategy = strategy_for_mesh(cfg, mesh)

    def frozen_leaf_spec(path: Tuple[str, ...], leaf) -> P:
        name = path[-1]
        shape = leaf.shape
        stacked = path[0] == "layers"  # leading n_layers dim
        off = 1 if stacked else 0

        def sd(*rules) -> P:
            shifted = [(d + off, ax) for d, ax in rules]
            if stacked:
                shifted = [(0, None)] + shifted
            return shard_dim_if(mesh, shape, *shifted)

        # --- embeddings / head ------------------------------------------
        if name == "embed":
            return shard_dim_if(mesh, shape, (0, mdl), (1, mdl))
        if name == "head":
            return shard_dim_if(mesh, shape, (1, mdl), (0, mdl))
        # --- attention ----------------------------------------------------
        if name in ("wq", "wk", "wv"):
            return sd((1, mdl))
        if name == "wo":
            return sd((0, mdl))
        if name in ("bq", "bk", "bv"):
            return sd((0, mdl))
        # --- dense MLP ------------------------------------------------------
        if name in ("w_gate", "w_up") and len(shape) == 2 + off:
            return sd((1, mdl))
        if name == "w_down" and len(shape) == 2 + off:
            return sd((0, mdl))
        # --- MoE experts (layout must match moe_shard_map strategy) ---------
        if name in ("w_gate", "w_up") and len(shape) == 3 + off:
            if moe_strategy == "ep_a2a":
                return sd((0, dp), (2, mdl))       # E over EP, f over TP
            if moe_strategy == "replicated":
                return sd()
            return sd((0, mdl), (2, dp))           # GSPMD fallback
        if name == "w_down" and len(shape) == 3 + off:
            if moe_strategy == "ep_a2a":
                return sd((0, dp), (1, mdl))
            if moe_strategy == "replicated":
                return sd()
            return sd((0, mdl), (1, dp))
        if name == "router":
            return sd()                            # routing must be replicated
        # shared expert (2D mats named like the dense MLP): TP over f under
        # ep_a2a (matches moe_shard_map's shared_spec); replicated otherwise
        if len(path) >= 2 and path[-2] == "shared" \
                and moe_strategy == "replicated":
            return sd()
        # --- mamba: replicate projections (small; avoids split-boundary
        #     collectives on the fused in_proj; DESIGN.md §3) ---------------
        if name in ("in_proj", "out_proj", "conv_w", "conv_b", "dt_bias",
                    "a_log", "d_skip", "gate_norm"):
            return sd()
        # --- norms / everything else: replicated ---------------------------
        return sd()

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return frozen_leaf_spec(path, tree)

    frozen_specs = walk(params["frozen"])

    # LoRA: mirror the base matrix's output sharding where divisible
    def lora_walk(tree, path=()):
        if isinstance(tree, dict) and set(tree.keys()) == {"a", "b"}:
            base = path[-1]
            out_axis = mdl if base in ("wq", "wk", "wv", "w_gate", "w_up") \
                else None
            stacked_off = 1 if path[0] == "layers" else 0
            a_spec = P(*([None] * (stacked_off + 2)))
            b_shape = tree["b"].shape
            rules = [(stacked_off + 1, out_axis)] if out_axis else []
            b_spec = shard_dim_if(mesh, b_shape,
                                  *([(0, None)] if stacked_off else []),
                                  *rules)
            return {"a": a_spec, "b": b_spec}
        if isinstance(tree, dict):
            return {k: lora_walk(v, path + (k,)) for k, v in tree.items()}
        return P(*([None] * len(tree.shape)))

    lora_specs = lora_walk(params["lora"])
    return {"frozen": frozen_specs, "lora": lora_specs}


def opt_state_specs(lora_specs: Params) -> Params:
    """AdamW m/v mirror the param specs; step is replicated."""
    return {"m": lora_specs, "v": lora_specs, "step": P()}


def batch_specs_for(cfg: ModelConfig, mesh: Mesh, kind: str,
                    global_batch: int = 0, cut: int = 0) -> Dict[str, P]:
    dp = data_axes(mesh)
    if global_batch and global_batch % _axis_size(mesh, dp) != 0:
        dp = None  # e.g. long_500k: batch=1 cannot shard; TP-only
    if kind == "train" and cut > 0:
        return {"smashed": P(dp, None, None), "labels": P(dp, None)}
    if cfg.input_mode == "embeds":
        inputs = {"embeds": P(dp, None, None)}
    else:
        inputs = {"tokens": P(dp, None)}
    if kind == "train":
        inputs["labels"] = P(dp, None)
    return inputs


def cache_specs(cfg: ModelConfig, cache: Params, mesh: Mesh,
                batch: int) -> Params:
    """Decode caches: KV sharded (batch -> data, slots -> model) — the
    sequence-sharded KV cache layout for long-context decode; SSM state
    sharded (batch -> data, heads-or-headdim -> model)."""
    dp = data_axes(mesh)
    dp_or_none = dp if batch % _axis_size(mesh, dp) == 0 else None

    def leaf_spec(path, leaf):
        name = path[-1]
        shape = leaf.shape  # leading n_layers dim
        if name in ("k", "v", "k_scale", "v_scale"):
            return shard_dim_if(mesh, shape, (1, dp_or_none), (2, "model"))
        if name == "h":      # (L, B, nh, hp, ns)
            return shard_dim_if(mesh, shape, (1, dp_or_none), (2, "model"),
                                (3, "model"))
        if name == "conv":   # (L, B, W-1, ch)
            return shard_dim_if(mesh, shape, (1, dp_or_none), (3, "model"))
        return P(*([None] * len(shape)))

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return leaf_spec(path, tree)

    return walk(cache)


def to_named(tree_specs: Params, mesh: Mesh) -> Params:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def attach(avals: Params, shardings: Params) -> Params:
    """ShapeDtypeStructs + shardings (dry-run inputs, no allocation)."""
    return jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        avals, shardings)
