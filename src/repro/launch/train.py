"""Training step factory + a runnable CPU-scale training driver.

``make_train_step`` builds the full fine-tune step (forward + LoRA-only
grads + AdamW update) that the multi-pod dry-run lowers; it is also what a
real pod job would run as the *server side* of the SL deployment at the
CARD cut (DESIGN.md §5).
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config
from repro.models import model as model_lib
from repro.models.common import Params
from repro.optim import Optimizer, adamw, apply_updates, warmup_cosine


def make_train_step(cfg: ModelConfig, optimizer: Optimizer, *,
                    impl: str = "chunked", remat: bool = True,
                    cut: int = 0, unroll: bool = False,
                    microbatches: int = 1) -> Callable:
    """Returns train_step(frozen, lora, opt_state, batch) ->
    (loss, new_lora, new_opt_state).

    ``cut > 0`` lowers only the server-resident stage [cut, I) + head — the
    device side runs on the edge fleet, so the pod job sees the smashed data
    as its input (dry-run exercises this via --cut).

    ``microbatches > 1`` splits the global batch and accumulates LoRA grads
    in fp32 via lax.scan — divides peak activation/dispatch memory by the
    microbatch count (required to fit kimi-k2 train_4k in 16 GB HBM chips).
    """

    def loss_fn(lora, frozen, batch):
        if cut == 0:
            return model_lib.forward_loss(frozen, lora, batch, cfg,
                                          impl=impl, remat=remat,
                                          unroll=unroll)
        smashed = batch["smashed"]
        x, aux = model_lib.forward_hidden(
            frozen, lora, smashed, cfg, lo=cut, hi=cfg.n_layers,
            impl=impl, remat=remat, inputs_embedded=True, unroll=unroll)
        logits = model_lib.logits_from_hidden(frozen, x, cfg)
        from repro.models.common import softmax_cross_entropy
        return softmax_cross_entropy(logits, batch["labels"]) + aux

    def train_step(frozen: Params, lora: Params, opt_state, batch
                   ) -> Tuple[jax.Array, Params, Any]:
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(lora, frozen, batch)
        else:
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def body(carry, mb_batch):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(loss_fn)(lora, frozen, mb_batch)
                grad_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), grad_acc, g)
                return (loss_acc + l, grad_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), lora)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), mb)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)

        updates, new_state = optimizer.update(grads, opt_state, lora)
        new_lora = apply_updates(lora, updates)
        return loss, new_lora, new_state

    return train_step


# ---------------------------------------------------------------------------
# CPU-scale driver: fine-tune a reduced model for a few hundred steps
# ---------------------------------------------------------------------------


def run_training(arch: str = "llama32-1b", steps: int = 200,
                 batch: int = 8, seq_len: int = 64, lr: float = 5e-3,
                 log_every: int = 20, seed: int = 0,
                 pretrain_steps: int = 60) -> Dict[str, Any]:
    from repro.data import make_fleet_datasets

    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(seed)
    params = model_lib.init_params(key, cfg)
    # pretraining task != fine-tuning task (domain shift for the LoRA phase)
    ds = make_fleet_datasets(cfg, 1, vocab=cfg.vocab_size, seed=seed)[0]
    ft_ds = make_fleet_datasets(cfg, 1, vocab=cfg.vocab_size,
                                seed=seed + 1000)[0]

    # brief full-param pretraining so the frozen backbone is a real
    # "pre-trained LLM" for the LoRA phase (paper Sec. II-A premise)
    opt_full = adamw(warmup_cosine(3e-3, 10, pretrain_steps))
    st = opt_full.init(params["frozen"])

    @jax.jit
    def pre_step(frozen, st, batch_):
        def lf(fr):
            return model_lib.forward_loss(fr, None, batch_, cfg,
                                          impl="naive", remat=False)
        loss, g = jax.value_and_grad(lf)(frozen)
        upd, st2 = opt_full.update(g, st, frozen)
        return apply_updates(frozen, upd), st2, loss

    frozen = params["frozen"]
    for _ in range(pretrain_steps):
        b = {k: jnp.asarray(v) for k, v in ds.minibatch(batch, seq_len).items()}
        frozen, st, pre_loss = pre_step(frozen, st, b)

    optimizer = adamw(warmup_cosine(lr, 20, steps))
    opt_state = optimizer.init(params["lora"])
    step_fn = jax.jit(make_train_step(cfg, optimizer, impl="naive",
                                      remat=False))
    lora = params["lora"]
    losses = []
    t0 = time.time()
    for i in range(steps):
        b = {k: jnp.asarray(v)
             for k, v in ft_ds.minibatch(batch, seq_len).items()}
        loss, lora, opt_state = step_fn(frozen, lora, opt_state, b)
        losses.append(loss)          # stays on device; no per-step sync
        if log_every and i % log_every == 0:
            # splint: ignore[trace-safety] -- log_every-gated progress sync
            print(f"step {i:4d} loss {float(loss):.4f}")
    losses = [float(v) for v in jax.device_get(losses)]
    return {"losses": losses, "pretrain_loss": float(pre_loss),
            "steps_per_sec": steps / (time.time() - t0), "lora": lora,
            "frozen": frozen, "cfg": cfg}


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama32-1b")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=64)
    p.add_argument("--lr", type=float, default=5e-3)
    args = p.parse_args()
    out = run_training(args.arch, args.steps, args.batch, args.seq_len,
                       args.lr)
    print(f"final loss {out['losses'][-1]:.4f} "
          f"({out['steps_per_sec']:.2f} steps/s)")


if __name__ == "__main__":
    main()
