import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with ShapeDtypeStruct inputs (no allocation).

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out dryrun.jsonl

Per combo this prints/records: memory_analysis (fits?), cost_analysis
(FLOPs/bytes for §Roofline), and the collective schedule parsed from the
lowered HLO. Failures here are bugs in the sharding config.
"""
import argparse     # noqa: E402
import dataclasses as _dc  # noqa: E402
import json         # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, InputShape,  # noqa: E402
                                ModelConfig, get_config,
                                long_context_variant)
from repro.data.pipeline import batch_specs  # noqa: E402
from repro.launch import sharding as shd  # noqa: E402
from repro.launch.analysis import analyze_compiled, model_flops  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.serve import make_prefill_step, make_serve_step  # noqa: E402
from repro.launch.train import make_train_step  # noqa: E402
from repro.models import model as model_lib  # noqa: E402
from repro.optim import adamw, constant_schedule  # noqa: E402

DRYRUN_ARCHS = tuple(a for a in ARCH_IDS if a != "llama32-1b")


def combo_config(arch: str, shape_name: str) -> Optional[ModelConfig]:
    """Config for (arch, shape) or None if the combo is skipped (DESIGN.md §4)."""
    cfg = get_config(arch)
    if shape_name == "long_500k":
        return long_context_variant(cfg)
    return cfg


def _lower_one(cfg: ModelConfig, shape: InputShape, mesh, *, cut: int,
               unroll: bool, compile_: bool, microbatches: int = 1,
               remat: bool = True):
    """Lower (and optionally compile) one step program. Returns
    (lowered, compiled_or_None)."""
    from repro import shardctx

    params_avals = model_lib.abstract_params(cfg)
    pspecs = shd.param_specs(cfg, params_avals, mesh)
    pshard = shd.to_named(pspecs, mesh)
    params_in = shd.attach(params_avals, pshard)
    bspecs = shd.to_named(shd.batch_specs_for(cfg, mesh, shape.kind,
                                              shape.global_batch, cut), mesh)

    with mesh, shardctx.mesh_ctx(mesh):
        if shape.kind == "train":
            optimizer = adamw(constant_schedule(1e-4))
            opt_avals = jax.eval_shape(optimizer.init, params_avals["lora"])
            opt_specs = shd.opt_state_specs(pspecs["lora"])
            opt_in = shd.attach(opt_avals, shd.to_named(opt_specs, mesh))
            step = make_train_step(cfg, optimizer, cut=cut, unroll=unroll,
                                    microbatches=microbatches, remat=remat)
            batch_avals = batch_specs(cfg, shape, cut)
            batch_in = shd.attach(batch_avals, bspecs)
            lowered = jax.jit(step, donate_argnums=(1, 2)).lower(
                params_in["frozen"], params_in["lora"], opt_in, batch_in)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, unroll=unroll)
            batch_avals = batch_specs(cfg, shape)
            key = "embeds" if cfg.input_mode == "embeds" else "tokens"
            inp = shd.attach({key: batch_avals[key]}, bspecs)[key]
            lowered = jax.jit(step).lower(
                params_in["frozen"], params_in["lora"], inp)
        else:  # decode
            step = make_serve_step(cfg, unroll=unroll)
            cache_avals = jax.eval_shape(
                lambda: model_lib.init_cache(cfg, shape.global_batch,
                                             shape.seq_len))
            cspecs = shd.cache_specs(cfg, cache_avals, mesh,
                                     shape.global_batch)
            cache_in = shd.attach(cache_avals, shd.to_named(cspecs, mesh))
            batch_avals = batch_specs(cfg, shape)
            key = "embeds" if cfg.input_mode == "embeds" else "tokens"
            inp = shd.attach({key: batch_avals[key]}, bspecs)[key]
            t_aval = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(step, donate_argnums=(2,)).lower(
                params_in["frozen"], params_in["lora"], cache_in, inp, t_aval)
        compiled = lowered.compile() if compile_ else None
    return lowered, compiled


def _cost_triple(compiled, chips) -> Dict:
    text = compiled.as_text()
    roof, coll, _mem = analyze_compiled(compiled, text, chips)
    return {"flops": roof.flops, "hbm_bytes": roof.hbm_bytes,
            "collective_bytes": float(coll.total_bytes),
            "counts": coll.counts, "bytes_by_kind": coll.bytes_by_kind}


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                cut: int = 0, compile_: bool = True, unroll: bool = False,
                roofline_probe: bool = True, microbatches: int = 1,
                remat: bool = True, capacity_factor: float = 0.0,
                kv_int8: bool = False) -> Dict:
    """Full-depth lower+compile (sharding proof + memory analysis) plus a
    depth-1/depth-2 unrolled probe pair for exact roofline terms:

      term(L) = term(1) + (L - 1) * (term(2) - term(1))

    XLA's HloCostAnalysis counts a scan body once regardless of trip count,
    so the full-depth scan numbers undercount by ~L x; the probe pair fixes
    that exactly for uniform layer stacks (all assigned archs are uniform).
    """
    cfg = combo_config(arch, shape_name)
    if capacity_factor:
        cfg = _dc.replace(cfg, capacity_factor=capacity_factor)
    if kv_int8:
        cfg = _dc.replace(cfg, kv_cache_dtype="int8")
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rec: Dict = {"arch": arch, "shape": shape_name, "unroll": unroll,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "cut": cut, "microbatches": microbatches, "remat": remat,
                 "capacity_factor": capacity_factor or None,
                 "kv_int8": kv_int8, "ok": False}

    t0 = time.time()
    lowered, compiled = _lower_one(cfg, shape, mesh, cut=cut, unroll=unroll,
                                   compile_=compile_,
                                   microbatches=microbatches, remat=remat)
    rec["lower_compile_s"] = round(time.time() - t0, 1)
    if not compile_:
        rec["ok"] = True
        return rec

    text = compiled.as_text()
    roof_raw, coll_raw, mem = analyze_compiled(compiled, text, chips)
    rec["memory"] = mem
    rec["raw_scan_costs"] = {"flops": roof_raw.flops,
                             "hbm_bytes": roof_raw.hbm_bytes,
                             "collective_bytes": float(coll_raw.total_bytes)}

    n_layers_eff = cfg.n_layers - cut
    if roofline_probe and not unroll and n_layers_eff >= 2:
        t1 = time.time()
        probes = []
        for depth in (1, 2):
            cfg_p = _dc.replace(cfg, n_layers=depth)
            # probes always run microbatches=1: total FLOPs/bytes per step
            # are mb-invariant (only *peak* memory changes, and that comes
            # from the full-depth compile's memory_analysis)
            _, comp_p = _lower_one(cfg_p, shape, mesh, cut=0, unroll=True,
                                   compile_=True, microbatches=1, remat=remat)
            probes.append(_cost_triple(comp_p, chips))
        rec["probe_s"] = round(time.time() - t1, 1)
        p1, p2 = probes
        L = n_layers_eff

        def extrap(key):
            return p1[key] + (L - 1) * (p2[key] - p1[key])

        flops = extrap("flops")
        hbm = extrap("hbm_bytes")
        coll_b = extrap("collective_bytes")
        counts = {k: p1["counts"].get(k, 0)
                  + (L - 1) * (p2["counts"].get(k, 0)
                               - p1["counts"].get(k, 0))
                  for k in set(p1["counts"]) | set(p2["counts"])}
        from repro.launch.analysis import Roofline
        roof = Roofline(flops=flops, hbm_bytes=hbm, collective_bytes=coll_b,
                        chips=chips)
        rec["collectives"] = {"counts": counts, "total_bytes": coll_b,
                              "per_layer_bytes":
                                  p2["collective_bytes"]
                                  - p1["collective_bytes"]}
    else:
        roof = roof_raw
        rec["collectives"] = {"counts": coll_raw.counts,
                              "bytes_by_kind": coll_raw.bytes_by_kind,
                              "total_bytes": float(coll_raw.total_bytes)}

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mf = model_flops(cfg, tokens,
                     "train" if shape.kind == "train" else "inference")
    rec.update({
        "ok": True,
        "roofline": roof.as_dict(),
        "model_flops_global": mf,
        "model_flops_per_chip": mf / chips,
        "useful_flops_ratio": (mf / chips) / roof.flops if roof.flops else None,
        "tokens": tokens,
    })
    return rec


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--mesh", choices=["single", "multi", "both"],
                   default="single")
    p.add_argument("--cut", type=int, default=0)
    p.add_argument("--no-compile", action="store_true")
    p.add_argument("--unroll", action="store_true",
                   help="unroll layers for exact cost_analysis FLOPs "
                        "(XLA counts scan bodies once)")
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--no-remat", action="store_true")
    p.add_argument("--capacity-factor", type=float, default=0.0)
    p.add_argument("--kv-int8", action="store_true")
    p.add_argument("--out", default=None, help="append JSONL records here")
    args = p.parse_args()

    combos = []
    archs = list(DRYRUN_ARCHS) if (args.all or args.arch in (None, "all")) \
        else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape in (None, "all")) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    n_fail = 0
    for a, s, mp in combos:
        label = f"{a} x {s} x {'2x16x16' if mp else '16x16'}"
        try:
            rec = lower_combo(a, s, multi_pod=mp, cut=args.cut,
                              compile_=not args.no_compile,
                              unroll=args.unroll,
                              microbatches=args.microbatches,
                              remat=not args.no_remat,
                              capacity_factor=args.capacity_factor,
                              kv_int8=args.kv_int8)
            r = rec.get("roofline", {})
            print(f"[OK]   {label}: lower {rec.get('lower_s')}s "
                  f"compile {rec.get('compile_s', '-')}s "
                  f"dominant={r.get('dominant', '-')} "
                  f"compute={r.get('compute_s', 0):.4g}s "
                  f"memory={r.get('memory_s', 0):.4g}s "
                  f"coll={r.get('collective_s', 0):.4g}s", flush=True)
        except Exception as e:
            n_fail += 1
            rec = {"arch": a, "shape": s,
                   "mesh": "2x16x16" if mp else "16x16", "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"[FAIL] {label}: {type(e).__name__}: {str(e)[:500]}",
                  flush=True)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
