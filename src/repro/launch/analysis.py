"""Compiled-artifact analysis: collective-bytes parsing + roofline terms.

Sources (§Roofline in EXPERIMENTS.md):
  * ``compiled.cost_analysis()``  -> HLO FLOPs, HLO bytes accessed
  * ``lowered/compiled.as_text()`` -> collective ops; we sum each
    all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute result size (bytes moved per device, SPMD view)

Hardware constants: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Tuple

from repro.core.hardware import (TPU_V5E_HBM_BW, TPU_V5E_ICI_BW,
                                 TPU_V5E_PEAK_BF16)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# result shapes like: bf16[8,4096,512]{2,1,0:T(8,128)(2,1)} or tuples
_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|f16|c64|c128)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*\)|[^ ]+)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"[\s(]", re.MULTILINE)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> str:
        parts = [f"{k}: n={self.counts.get(k, 0)} "
                 f"{self.bytes_by_kind.get(k, 0) / 1e9:.3f} GB"
                 for k in COLLECTIVE_KINDS if self.counts.get(k)]
        return "; ".join(parts) if parts else "none"


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective op (per-device view)."""
    stats = CollectiveStats()
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        b = _shape_bytes(shape_str)
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
    return stats


@dataclass
class Roofline:
    """Per-device roofline terms, seconds."""
    flops: float                 # HLO FLOPs (per device)
    hbm_bytes: float             # HLO bytes accessed (per device)
    collective_bytes: float      # per device
    chips: int
    ici_links: int = 4           # v5e 2D torus: 4 links/chip

    @property
    def compute_s(self) -> float:
        return self.flops / TPU_V5E_PEAK_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / TPU_V5E_HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (TPU_V5E_ICI_BW * self.ici_links)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
        }


def model_flops(cfg, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n = cfg.active_params()
    factor = 6.0 if kind == "train" else 2.0
    return factor * n * tokens


def analyze_compiled(compiled, lowered_text: str, chips: int) -> Tuple[Roofline, CollectiveStats, Dict]:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(lowered_text)
    roof = Roofline(flops=flops, hbm_bytes=hbm,
                    collective_bytes=float(coll.total_bytes), chips=chips)
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_info = {"error": str(e)}
    return roof, coll, mem_info
