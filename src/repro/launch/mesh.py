"""Production meshes.

Single pod: 16 x 16 = 256 TPU-v5e chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is data-parallel across pods (each pod serves one group of edge
devices in the SL deployment; DESIGN.md §3).

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_abstract_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Device-free mesh for sharding-rule checks, across JAX API revisions:
    0.4.x takes ((name, size), ...) pairs; newer takes (sizes, names)."""
    try:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape,
                                                   strict=True)))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes that shard the batch dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """A CPU-sized mesh for tests."""
    return jax.make_mesh(shape, axes)


def make_fleet_mesh(n_shards: int = 0):
    """A 1-D ``("data",)`` mesh that shards the *devices* axis of a fleet
    sweep (``simulate_fleet(..., mesh=...)``). ``n_shards=0`` uses every
    host device. Distinct from the 2-D model meshes above: fleet sweeps
    have no model axis — each lane is one edge device's decision problem.
    """
    if n_shards <= 0:
        n_shards = len(jax.devices())
    return jax.make_mesh((n_shards,), ("data",))


def fleet_shard_map(fn, mesh, in_specs, out_specs):
    """``jax.shard_map`` across JAX API revisions (0.4.x keeps it under
    ``jax.experimental.shard_map`` with ``check_rep`` instead of
    ``check_vma``) — same shim as ``models/moe_shard_map.py``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _esm
    return _esm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
