"""Production meshes.

Single pod: 16 x 16 = 256 TPU-v5e chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is data-parallel across pods (each pod serves one group of edge
devices in the SL deployment; DESIGN.md §3).

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_abstract_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Device-free mesh for sharding-rule checks, across JAX API revisions:
    0.4.x takes ((name, size), ...) pairs; newer takes (sizes, names)."""
    try:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape,
                                                   strict=True)))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes that shard the batch dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """A CPU-sized mesh for tests."""
    return jax.make_mesh(shape, axes)
