"""Channel-aware admission control: serving shares the edge bandwidth budget.

The paper's edge server fine-tunes the fleet over a wireless link
(``core/channel.py``: pathloss -> SNR -> CQI -> spectral efficiency); the
same link streams generated tokens back to users at inference time. The
controller reserves a fraction of the band for SL training and admits a
request only while the unreserved capacity covers the bandwidth its token
stream needs at the efficiency of a per-request channel draw:

    demand_hz = token_rate_per_s * bits_per_token / efficiency(snr_down)

A request that does not fit waits in the engine queue (FIFO); the grant is
released on completion. One head-of-line request is always admitted when
nothing else holds a grant, so a single oversized demand degrades service
instead of deadlocking it. Per-tenant (adapter_id) queueing stats make the
contention visible.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.core.channel import (CQI_EFFICIENCY, DEFAULT_DISTANCE_M,
                                WirelessChannel, snr_to_efficiency)


class ChannelAdmissionController:
    """Bandwidth-budget admission for the serving engine.

    Parameters mirror ``WirelessChannel``; ``training_reserve_frac`` is the
    share of the band kept for SL fine-tuning traffic, ``token_rate_per_s``
    the per-user token stream rate and ``bits_per_token`` its wire size.
    """

    def __init__(self, *, bandwidth_hz: float = 20e6,
                 training_reserve_frac: float = 0.5,
                 token_rate_per_s: float = 20.0,
                 bits_per_token: float = 32.0,
                 channel_state: str = "normal",
                 distance_m: float = DEFAULT_DISTANCE_M, seed: int = 0):
        if not 0.0 <= training_reserve_frac < 1.0:
            raise ValueError("training_reserve_frac must be in [0, 1)")
        self.channel = WirelessChannel(channel_state, distance_m=distance_m,
                                       bandwidth_hz=bandwidth_hz, seed=seed)
        self.capacity_hz = bandwidth_hz * (1.0 - training_reserve_frac)
        self.reserved_hz = bandwidth_hz - self.capacity_hz
        self.token_rate_per_s = token_rate_per_s
        self.bits_per_token = bits_per_token
        self.used_hz = 0.0
        self._demand_hz: Dict[int, float] = {}      # uid -> bandwidth demand
        self._granted: Dict[int, float] = {}        # uid -> granted demand
        self.forced_admits = 0
        self._tenants: Dict[int, Dict[str, Any]] = {}

    def _tenant(self, adapter_id: int) -> Dict[str, Any]:
        return self._tenants.setdefault(adapter_id, {
            "submitted": 0, "admitted": 0, "completed": 0,
            "blocked_attempts": 0, "wait_s_sum": 0.0, "demand_hz_sum": 0.0,
        })

    def register(self, req) -> None:
        """Draw this request's channel and price its bandwidth demand."""
        state = self.channel.draw()
        eff = max(snr_to_efficiency(state.snr_down_db), CQI_EFFICIENCY[0])
        bps = self.token_rate_per_s * self.bits_per_token
        self._demand_hz[req.uid] = bps / eff
        tenant = self._tenant(req.adapter_id)
        tenant["submitted"] += 1
        tenant["demand_hz_sum"] += self._demand_hz[req.uid]

    def try_admit(self, req, now: float) -> bool:
        demand_hz = self._demand_hz.get(req.uid)
        if demand_hz is None:           # unregistered: admit unmetered
            return True
        tenant = self._tenant(req.adapter_id)
        fits = self.used_hz + demand_hz <= self.capacity_hz
        if not fits and self._granted:
            tenant["blocked_attempts"] += 1
            return False
        if not fits:
            self.forced_admits += 1     # head-of-line liveness
        self.used_hz += demand_hz
        self._granted[req.uid] = demand_hz
        tenant["admitted"] += 1
        tenant["wait_s_sum"] += max(now - req.submitted_at, 0.0)
        return True

    def release(self, req, now: float) -> None:
        granted = self._granted.pop(req.uid, None)
        if granted is None:
            return
        self.used_hz = max(self.used_hz - granted, 0.0)
        if not self._granted:
            self.used_hz = 0.0          # clear float residue at idle
        self._demand_hz.pop(req.uid, None)
        self._tenant(req.adapter_id)["completed"] += 1

    def stats(self) -> Dict[str, Any]:
        tenants = {}
        for aid, t in sorted(self._tenants.items()):
            admitted = t["admitted"]
            tenants[aid] = {
                **t,
                "mean_wait_s": t["wait_s_sum"] / admitted if admitted else None,
                "mean_demand_hz": (t["demand_hz_sum"] / t["submitted"]
                                   if t["submitted"] else None),
            }
        return {
            "capacity_hz": self.capacity_hz,
            "reserved_hz": self.reserved_hz,
            "used_hz": self.used_hz,
            "in_flight": len(self._granted),
            "forced_admits": self.forced_admits,
            "tenants": tenants,
        }
