from repro.serving.admission import ChannelAdmissionController  # noqa: F401
from repro.serving.engine import (AdapterBank, Request,  # noqa: F401
                                  ServingEngine)
