"""Continuous-batching serving engine over the cached decode path.

Deploys the SL-fine-tuned model: a fixed pool of batch slots shares one
stacked KV/SSM cache; requests are admitted into free slots as others
finish (continuous batching), every engine tick runs ONE jitted
``decode_step`` for the whole pool, and per-slot state tracks prompt
feeding vs generation. Slot recycling resets only that slot's cache lanes.

This is the decode_32k/long_500k dry-run shape driven end-to-end: the
engine's ``step_fn`` is exactly what those combos lower at pod scale.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.models.common import Params


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (S0,) int32 tokens
    max_new: int
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.finished_at is not None


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    pos: int = 0                        # next absolute position to write
    fed: int = 0                        # prompt tokens consumed

    @property
    def free(self) -> bool:
        return self.request is None


class ServingEngine:
    """Greedy continuous batching; one decode_step per tick for all slots."""

    def __init__(self, cfg: ModelConfig, frozen: Params,
                 lora: Optional[Params], *, slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None):
        self.cfg = cfg
        self.frozen = frozen
        self.lora = lora
        self.n_slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = model_lib.init_cache(cfg, slots, max_len)
        self._zero_cache = jax.tree_util.tree_map(jnp.zeros_like, self.cache)
        self.slots = [_Slot() for _ in range(slots)]
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self.ticks = 0

        # one token per slot per tick; positions differ per slot, so decode
        # uses per-slot position via vmap-of-t? decode_step takes a single t —
        # we keep per-slot positions aligned by feeding pad tokens into free
        # slots and tracking validity host-side. Positions must therefore be
        # per-slot: we shard the step over slots with vmap.
        def one(frozen, lora, cache, tok, t):
            # vmap maps over the cache's batch axis (1); decode_step expects
            # it present — reinsert a singleton batch dim per slot
            cache_b = jax.tree_util.tree_map(lambda c: c[:, None], cache)
            logits, new_cache = model_lib.decode_step(
                frozen, lora, cache_b, tok[None, :], t, cfg)
            return logits[0], jax.tree_util.tree_map(
                lambda c: c[:, 0], new_cache)

        self._step = jax.jit(jax.vmap(one, in_axes=(None, None, 1, 0, 0),
                                      out_axes=(0, 1)))

    # --- API -------------------------------------------------------------

    def submit(self, req: Request) -> None:
        req.submitted_at = time.time()
        self.queue.append(req)

    def _admit(self) -> None:
        for slot_idx, slot in enumerate(self.slots):
            if slot.free and self.queue:
                req = self.queue.pop(0)
                slot.request = req
                slot.pos = 0
                slot.fed = 0
                # reset this slot's cache lanes
                self.cache = jax.tree_util.tree_map(
                    lambda c, z, i=slot_idx: c.at[:, i].set(z[:, i]),
                    self.cache, self._zero_cache)

    def tick(self) -> int:
        """One engine step; returns number of active slots."""
        self._admit()
        active = [s for s in self.slots if not s.free]
        if not active:
            return 0

        toks = np.zeros((self.n_slots, 1), np.int32)
        ts = np.zeros((self.n_slots,), np.int32)
        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            req = slot.request
            if slot.fed < len(req.prompt):
                toks[i, 0] = int(req.prompt[slot.fed])      # prefill feed
            elif req.output:
                toks[i, 0] = req.output[-1]                  # autoregressive
            ts[i] = slot.pos

        logits, self.cache = self._step(
            self.frozen, self.lora, self.cache,
            jnp.asarray(toks), jnp.asarray(ts))
        logits = np.asarray(logits)
        now = time.time()

        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            req = slot.request
            slot.pos += 1
            if slot.fed < len(req.prompt):
                slot.fed += 1
                if slot.fed < len(req.prompt):
                    continue            # still consuming the prompt
            nxt = int(np.argmax(logits[i, :self.cfg.vocab_size]))
            if req.first_token_at is None:
                req.first_token_at = now
            req.output.append(nxt)
            hit_eos = self.eos_id is not None and nxt == self.eos_id
            if len(req.output) >= req.max_new or hit_eos \
                    or slot.pos >= self.max_len - 1:
                req.finished_at = now
                self.completed.append(req)
                slot.request = None
        self.ticks += 1
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> Dict[str, Any]:
        t0 = time.time()
        while (self.queue or any(not s.free for s in self.slots)) \
                and self.ticks < max_ticks:
            self.tick()
        wall = time.time() - t0
        toks = sum(len(r.output) for r in self.completed)
        return {
            "completed": len(self.completed),
            "ticks": self.ticks,
            "tokens": toks,
            "tokens_per_sec": toks / max(wall, 1e-9),
            "mean_ttft_s": float(np.mean(
                [r.first_token_at - r.submitted_at
                 for r in self.completed if r.first_token_at])) if
            self.completed else None,
        }
