"""Multi-tenant continuous-batching serving engine over the cached decode path.

Deploys the SL-fine-tuned *fleet*: a fixed pool of batch slots shares one
stacked KV/SSM cache and ONE frozen backbone, while every slot decodes with
its own LoRA adapter — the fleet's adapters are stacked into an
``(n_adapters, ...)`` bank and each slot's pair is gathered *inside* the
jitted step (``AdapterBank``), so one tick serves N users x N adapters.

Per-tick work is a single jitted ``decode_step`` with a per-slot position
vector; prompt chunks are consumed by a jitted multi-token prefill
(``model.prefill_chunk`` for attention families, exact ``model.decode_scan``
for cumulative-state SSM/hybrid) before the slot joins the decode pool, so
TTFT no longer scales as ``len(prompt) x tick_latency``.

Slot recycling is lazy and copy-free: stale KV lanes are hidden by the
causal/ring position masks (a request at position t only ever attends lanes
it has itself written), and SSM state is zeroed inside the jitted step for
rows starting at position 0. Admission never touches the cache.

Admission can be gated by a channel-aware controller
(``repro.serving.admission``) so serving and SL training share the edge
bandwidth budget.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.models.common import Params


class AdapterBank:
    """The fleet's LoRA adapters stacked into one ``(n_adapters, ...)`` tree.

    All adapters must share one tree structure and per-leaf shape (they come
    from the same ``init_params`` config, fine-tuned per device). ``stacked``
    leaves are ``(n_adapters, n_layers, ...)``; ``gather(ids)`` returns the
    per-row adapter tree ``decode_step`` consumes (leaves
    ``(n_layers, B, ...)`` so the layer scan slices to ``(B, ...)`` and
    every LoRA matmul batch-broadcasts row-wise).
    """

    def __init__(self, adapters: Sequence[Params]):
        adapters = list(adapters)
        if not adapters:
            raise ValueError("AdapterBank needs at least one adapter")
        ref = jax.tree_util.tree_structure(adapters[0])
        for i, a in enumerate(adapters[1:], start=1):
            if jax.tree_util.tree_structure(a) != ref:
                raise ValueError(
                    f"adapter {i} tree structure differs from adapter 0")
        self.n = len(adapters)
        self.stacked: Params = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *adapters)

    @staticmethod
    def gather(stacked: Params, ids: jax.Array) -> Params:
        """stacked["layers"] leaves (E, n_layers, ...) + ids (B,) ->
        {"layers": leaves (n_layers, B, ...)}. Trace-safe (used in jit)."""
        return {"layers": jax.tree_util.tree_map(
            lambda v: jnp.moveaxis(v[ids], 0, 1), stacked["layers"])}


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (S0,) int32 tokens
    max_new: int
    adapter_id: int = 0                 # index into the engine's AdapterBank
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    admitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    truncated: bool = False             # max_new clipped at submit()

    @property
    def done(self) -> bool:
        return self.finished_at is not None


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    pos: int = 0                        # next absolute position to write
    fed: int = 0                        # prompt tokens consumed

    @property
    def free(self) -> bool:
        return self.request is None


class ServingEngine:
    """Greedy continuous batching; one decode_step per tick for all slots.

    ``lora`` may be a single adapter tree, a list of adapter trees, or an
    ``AdapterBank`` — requests pick theirs via ``Request.adapter_id``.

    ``on_overflow`` decides what ``submit`` does with a request whose
    ``len(prompt) + max_new`` exceeds ``max_len``: ``"reject"`` raises,
    ``"truncate"`` clips ``max_new`` and sets ``Request.truncated``.
    """

    def __init__(self, cfg: ModelConfig, frozen: Params,
                 lora: Union[Params, Sequence[Params], AdapterBank, None],
                 *, slots: int = 4, max_len: int = 256,
                 eos_id: Optional[int] = None, prefill_chunk: int = 16,
                 admission=None, on_overflow: str = "reject",
                 use_lora_kernel: bool = False):
        if on_overflow not in ("reject", "truncate"):
            raise ValueError("on_overflow must be 'reject' or 'truncate'")
        self.cfg = cfg
        self.frozen = frozen
        if lora is None:
            self.bank: Optional[AdapterBank] = None
        elif isinstance(lora, AdapterBank):
            self.bank = lora
        elif isinstance(lora, (list, tuple)):
            self.bank = AdapterBank(lora)
        else:
            self.bank = AdapterBank([lora])
        self.n_adapters = 0 if self.bank is None else self.bank.n
        self.n_slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.admission = admission
        self.on_overflow = on_overflow
        self.use_lora_kernel = use_lora_kernel
        self.cache = model_lib.init_cache(cfg, slots, max_len)
        self.slots = [_Slot() for _ in range(slots)]
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self.ticks = 0
        self.prefills = 0

        # chunked prefill: parallel cache-writing forward for attention
        # families; exact in-jit decode scan for cumulative-state SSM/hybrid.
        # The parallel path writes a chunk's K/V in one scatter, so a chunk
        # must fit in the cache ring (chunk <= slot count of the KV cache).
        self._prefill_mode = None
        self._chunk = 0
        if prefill_chunk > 1 and cfg.input_mode == "tokens":
            self._prefill_mode = "scan" if cfg.has_ssm else "parallel"
            self._chunk = prefill_chunk
            if self._prefill_mode == "parallel" and cfg.family != "ssm":
                kv_slots = int(jax.tree_util.tree_leaves(
                    self.cache["kv"])[0].shape[2])
                self._chunk = min(self._chunk, kv_slots)
                if self._chunk < 2:
                    self._prefill_mode, self._chunk = None, 0

        def tick_fn(frozen, stacked, cache, toks, ts, ids):
            lora_b = (None if stacked is None
                      else AdapterBank.gather(stacked, ids))
            cache = self._lazy_ssm_reset(cache, ts != 0)
            return model_lib.decode_step(
                frozen, lora_b, cache, toks, ts, cfg,
                use_lora_kernel=use_lora_kernel)

        def prefill_fn(frozen, stacked, cache, toks, slot, t0, aid):
            # extract ONE slot lane, run the chunk, write the lane back —
            # never touches the other slots' in-flight lanes.
            lane = jax.tree_util.tree_map(
                lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1),
                cache)
            lane = self._lazy_ssm_reset(lane, (t0 != 0)[None])
            lora_b = None
            if stacked is not None:
                lora_b = {"layers": jax.tree_util.tree_map(
                    lambda v: v[aid], stacked["layers"])}
            if self._prefill_mode == "parallel":
                logits, lane = model_lib.prefill_chunk(
                    frozen, lora_b, lane, toks, t0, cfg,
                    use_lora_kernel=use_lora_kernel)
            else:
                logits, lane = model_lib.decode_scan(
                    frozen, lora_b, lane, toks, t0, cfg,
                    use_lora_kernel=use_lora_kernel)
            cache = jax.tree_util.tree_map(
                lambda c, la: jax.lax.dynamic_update_slice_in_dim(
                    c, la, slot, axis=1),
                cache, lane)
            return logits, cache

        self._step = jax.jit(tick_fn)
        self._prefill = jax.jit(prefill_fn) if self._prefill_mode else None

    @staticmethod
    def _lazy_ssm_reset(cache: Params, keep: jax.Array) -> Params:
        """Zero SSM lanes of rows starting a new request (position 0).

        KV lanes need no reset at all: the causal/ring position masks in
        ``attention_decode`` only expose lanes the current request has
        itself written. SSM state is cumulative, so it is reset in-jit —
        no host-side cache copy ever happens on admission.
        """
        if "ssm" not in cache:
            return cache
        def mask(c):
            # c: (n_layers, B, ...); keep: (B,)
            shape = (1, c.shape[1]) + (1,) * (c.ndim - 2)
            return jnp.where(keep.reshape(shape), c, jnp.zeros((), c.dtype))
        return {**cache,
                "ssm": jax.tree_util.tree_map(mask, cache["ssm"])}

    # --- API -------------------------------------------------------------

    def submit(self, req: Request) -> None:
        if self.bank is not None and not (0 <= req.adapter_id < self.bank.n):
            raise ValueError(
                f"request {req.uid}: adapter_id {req.adapter_id} out of "
                f"range for a bank of {self.bank.n}")
        need = len(req.prompt) + req.max_new
        if need > self.max_len:
            if self.on_overflow == "truncate":
                clipped = self.max_len - len(req.prompt)
                if clipped <= 0:
                    raise ValueError(
                        f"request {req.uid}: prompt of {len(req.prompt)} "
                        f"tokens alone exceeds max_len={self.max_len}")
                req.max_new = clipped
                req.truncated = True
            else:
                raise ValueError(
                    f"request {req.uid}: len(prompt) + max_new = {need} "
                    f"exceeds max_len = {self.max_len}; decode past the "
                    "cache end would corrupt the last cache lane "
                    "(on_overflow='truncate' clips instead)")
        req.submitted_at = time.time()
        if self.admission is not None:
            self.admission.register(req)
        self.queue.append(req)

    def _stacked(self):
        return None if self.bank is None else self.bank.stacked

    def _admit(self) -> None:
        for slot_idx, slot in enumerate(self.slots):
            if not slot.free or not self.queue:
                continue
            req = self.queue[0]
            now = time.time()
            if self.admission is not None \
                    and not self.admission.try_admit(req, now):
                break                   # FIFO: head-of-line blocks the rest
            self.queue.pop(0)
            req.admitted_at = now
            slot.request = req
            slot.pos = 0
            slot.fed = 0
            # NO cache reset here (see _lazy_ssm_reset) — admission is O(1).
            self._prefill_slot(slot_idx, slot, req)

    def _prefill_slot(self, slot_idx: int, slot: _Slot, req: Request) -> None:
        """Consume all full prompt chunks in jitted multi-token steps; any
        ragged tail is fed token-by-token by the decode tick (keeping chunk
        shapes static means exactly one compile per engine)."""
        if not self._chunk:
            return
        n_full = len(req.prompt) // self._chunk
        if n_full == 0:
            return
        logits = None
        for ci in range(n_full):
            lo = ci * self._chunk
            toks = jnp.asarray(
                np.asarray(req.prompt[lo:lo + self._chunk],
                           np.int32)[None, :])
            logits, self.cache = self._prefill(
                self.frozen, self._stacked(), self.cache, toks,
                jnp.int32(slot_idx), jnp.int32(slot.pos),
                jnp.int32(req.adapter_id))
            slot.pos += self._chunk
            slot.fed += self._chunk
            self.prefills += 1
        if slot.fed == len(req.prompt):
            # the whole prompt was chunk-consumed: the first output token
            # comes straight from the prefill logits (this is the TTFT win)
            nxt = int(np.argmax(
                np.asarray(logits)[0, :self.cfg.vocab_size]))
            self._emit(slot, req, nxt, time.time())

    def _emit(self, slot: _Slot, req: Request, nxt: int, now: float) -> None:
        """Record one generated token and retire the request when done."""
        if req.first_token_at is None:
            req.first_token_at = now
        req.output.append(nxt)
        hit_eos = self.eos_id is not None and nxt == self.eos_id
        if len(req.output) >= req.max_new or hit_eos \
                or slot.pos >= self.max_len - 1:
            req.finished_at = now
            self.completed.append(req)
            slot.request = None
            if self.admission is not None:
                self.admission.release(req, now)

    def tick(self) -> int:
        """One engine step; returns number of active slots."""
        self._admit()
        active = [s for s in self.slots if not s.free]
        if not active:
            return 0

        toks = np.zeros((self.n_slots, 1), np.int32)
        ts = np.zeros((self.n_slots,), np.int32)
        ids = np.zeros((self.n_slots,), np.int32)
        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            req = slot.request
            if slot.fed < len(req.prompt):
                toks[i, 0] = int(req.prompt[slot.fed])      # prompt feed
            elif req.output:
                toks[i, 0] = req.output[-1]                  # autoregressive
            ts[i] = slot.pos
            ids[i] = req.adapter_id

        logits, self.cache = self._step(
            self.frozen, self._stacked(), self.cache,
            jnp.asarray(toks), jnp.asarray(ts), jnp.asarray(ids))
        logits = np.asarray(logits)
        now = time.time()

        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            req = slot.request
            slot.pos += 1
            if slot.fed < len(req.prompt):
                slot.fed += 1
                if slot.fed < len(req.prompt):
                    continue            # still consuming the prompt
            nxt = int(np.argmax(logits[i, :self.cfg.vocab_size]))
            self._emit(slot, req, nxt, now)
        self.ticks += 1
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> Dict[str, Any]:
        t0 = time.time()
        while (self.queue or any(not s.free for s in self.slots)) \
                and self.ticks < max_ticks:
            n = self.tick()
            if n == 0 and self.queue:
                # nothing in flight and the admission controller refused the
                # head of the queue: no future tick can make progress
                break
        return self._summary(time.time() - t0)

    def _summary(self, wall_s: float) -> Dict[str, Any]:
        toks = sum(len(r.output) for r in self.completed)
        in_flight = sum(not s.free for s in self.slots)
        ttfts = [r.first_token_at - r.submitted_at for r in self.completed
                 if r.first_token_at is not None]
        stats: Dict[str, Any] = {
            "completed": len(self.completed),
            "ticks": self.ticks,
            "prefills": self.prefills,
            "tokens": toks,
            "tokens_per_sec": toks / max(wall_s, 1e-9),
            "requests_per_s": len(self.completed) / max(wall_s, 1e-9),
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else None,
            "drained": not self.queue and in_flight == 0,
            "pending": {"queued": len(self.queue), "in_flight": in_flight},
            "wall_s": wall_s,
        }
        if self.admission is not None:
            stats["admission"] = self.admission.stats()
        return stats
