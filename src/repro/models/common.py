"""Shared building blocks: norms, rotary embeddings, initializers, LoRA dense.

All models are pure-functional pytrees: ``init_*`` returns a nested dict of
jnp arrays, ``*_apply`` consumes it. Matmuls accumulate in fp32 via
``preferred_element_type`` regardless of the storage dtype.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

ACC_DTYPE = jnp.float32


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(ACC_DTYPE)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * scale.astype(ACC_DTYPE)).astype(x.dtype)


def init_rms_norm(dim: int, dtype=jnp.float32) -> jax.Array:
    return jnp.ones((dim,), dtype=dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,seq,hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (...,seq,1,hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    std = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# LoRA
# ---------------------------------------------------------------------------


def init_lora_pair(key, in_dim: int, out_dim: int, rank: int, dtype=jnp.float32) -> Params:
    ka, _ = jax.random.split(key)
    # A ~ N(0, 1/r), B = 0 (standard LoRA init: delta starts at zero)
    a = jax.random.normal(ka, (in_dim, rank), jnp.float32) / math.sqrt(rank)
    return {"a": a.astype(dtype), "b": jnp.zeros((rank, out_dim), dtype)}


def lora_dense(
    x: jax.Array,
    w: jax.Array,
    lora: Optional[Params],
    scale: float,
    bias: Optional[jax.Array] = None,
    use_kernel: bool = False,
) -> jax.Array:
    """y = x @ W (+bias) + scale * (x @ A) @ B.

    ``use_kernel=True`` routes through the fused Pallas TPU kernel
    (``repro.kernels.lora_matmul``); the default is the pure-jnp path that
    XLA fuses on any backend.

    Each matmul output is cast to the activation dtype *immediately*: the
    MXU still accumulates in f32 internally, but tensor-parallel partial
    sums then cross the ICI as bf16 — this halved the measured TP
    all-reduce bytes (EXPERIMENTS.md §Perf-3).
    """
    if use_kernel and lora is not None:
        from repro.kernels import ops as kernel_ops

        if lora["a"].ndim == 3:
            # per-row adapters (multi-tenant serving: one gathered pair per
            # request row) -> grouped kernel, one grid cell per row
            ids = jnp.arange(x.shape[0], dtype=jnp.int32)
            y = kernel_ops.lora_matmul_grouped(x, w, lora["a"], lora["b"],
                                               ids, scale)
        else:
            y = kernel_ops.lora_matmul(x, w, lora["a"], lora["b"], scale)
        if bias is not None:
            y = y + bias.astype(y.dtype)
        return y
    y = jnp.matmul(x, w.astype(x.dtype),
                   preferred_element_type=ACC_DTYPE).astype(x.dtype)
    if lora is not None:
        xa = jnp.matmul(x, lora["a"].astype(x.dtype),
                        preferred_element_type=ACC_DTYPE).astype(x.dtype)
        y = y + (scale * jnp.matmul(
            xa, lora["b"].astype(x.dtype),
            preferred_element_type=ACC_DTYPE)).astype(x.dtype)
    if bias is not None:
        y = y + bias.astype(x.dtype)
    return y


def maybe_lora(lora_tree: Optional[Params], name: str) -> Optional[Params]:
    if lora_tree is None:
        return None
    return lora_tree.get(name)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x.astype(ACC_DTYPE)).astype(x.dtype)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          ignore_id: int = -100) -> jax.Array:
    """Mean next-token CE. logits: (B,S,V) fp; labels: (B,S) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))
