"""Mamba-2 (SSD, arXiv:2405.21060) block: chunked parallel scan for
train/prefill, O(1)-state recurrence for decode.

Single B/C group (ngroups=1), head structure (nh heads x hp head_dim).
The chunked SSD math here is the pure-jnp oracle shared with
``repro.kernels.ssd_scan``; the Pallas kernel implements the intra-chunk
part with VMEM tiling.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (ACC_DTYPE, Params, dense_init,
                                 init_lora_pair, init_rms_norm, lora_dense,
                                 maybe_lora, rms_norm, silu)


def init_mamba(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    di, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads
    conv_ch = di + 2 * ns
    ks = jax.random.split(key, 5)
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,), jnp.float32)
                 * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    return {
        # in_proj -> [z(di), x(di), B(ns), C(ns), dt(nh)]
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * ns + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch),
                                     jnp.float32) / math.sqrt(cfg.ssm_conv_width)
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "gate_norm": init_rms_norm(di),
        "out_proj": dense_init(ks[3], di, d, dtype),
    }


def init_mamba_lora(key, cfg: ModelConfig) -> Params:
    r, d, di = cfg.lora.rank, cfg.d_model, cfg.ssm_d_inner
    ldt = jnp.dtype(cfg.lora.dtype)
    k1, k2 = jax.random.split(key)
    return {
        "in_proj": init_lora_pair(k1, d, 2 * di + 2 * cfg.ssm_state
                                  + cfg.ssm_n_heads, r, ldt),
        "out_proj": init_lora_pair(k2, di, d, r, ldt),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 prefix: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv. xbc: (B,L,C); w: (W,C). prefix: (B,W-1,C)."""
    width = w.shape[0]
    if prefix is None:
        prefix = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    xp = jnp.concatenate([prefix, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(width))
    return silu(out + b)


def ssd_chunked(xt: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array,
                chunk: int, h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD scan. xt: (B,L,nh,hp) pre-multiplied by dt; a: (B,L,nh) = A*dt
    (<=0); B,C: (B,L,ns). Returns (y: (B,L,nh,hp), h_final: (B,nh,hp,ns))."""
    b, l, nh, hp = xt.shape
    ns = B.shape[-1]
    pad = (-l) % chunk
    if pad:
        xt = jnp.pad(xt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = xt.shape[1] // chunk
    xt = xt.reshape(b, nc, chunk, nh, hp).astype(ACC_DTYPE)
    a = a.reshape(b, nc, chunk, nh).astype(ACC_DTYPE)
    Bc = B.reshape(b, nc, chunk, ns).astype(ACC_DTYPE)
    Cc = C.reshape(b, nc, chunk, ns).astype(ACC_DTYPE)

    cum = jnp.cumsum(a, axis=2)                          # (b,nc,cl,nh)
    # intra-chunk (quadratic within chunk)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,i,j,nh)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)       # (b,nc,i,j)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp",
                        scores, decay, xt)

    # chunk-final states
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (b,nc,cl,nh)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, dec_end, xt)

    # inter-chunk recurrence
    a_tot = jnp.exp(cum[:, :, -1, :])                    # (b,nc,nh)
    if h0 is None:
        h0 = jnp.zeros((b, nh, hp, ns), ACC_DTYPE)
    else:
        h0 = h0.astype(ACC_DTYPE)

    def step(h, inp):
        at, st = inp                                     # (b,nh),(b,nh,hp,ns)
        h_new = h * at[:, :, None, None] + st
        return h_new, h

    h_final, h_prevs = jax.lax.scan(
        step, h0, (a_tot.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)           # (b,nc,nh,hp,ns)

    y_off = jnp.einsum("bcin,bcihpn->bcihp",
                       Cc, jnp.exp(cum)[..., None, None]
                       * h_prevs[:, :, None], )
    y = (y_diag + y_off).reshape(b, nc * chunk, nh, hp)
    return y[:, :l], h_final


def mamba_forward(params: Params, lora: Optional[Params], x: jax.Array,
                  cfg: ModelConfig, use_lora_kernel: bool = False
                  ) -> jax.Array:
    """Full-sequence forward. x: (B,L,d) -> (B,L,d)."""
    di, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads
    hp = cfg.ssm_head_dim
    proj = lora_dense(x, params["in_proj"], maybe_lora(lora, "in_proj"),
                      cfg.lora.scale, use_kernel=use_lora_kernel)
    z, xs, B, C, dt_raw = jnp.split(
        proj, [di, 2 * di, 2 * di + ns, 2 * di + 2 * ns], axis=-1)
    xbc = _causal_conv(jnp.concatenate([xs, B, C], -1),
                       params["conv_w"], params["conv_b"])
    xs, B, C = jnp.split(xbc, [di, di + ns], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"])                        # (nh,)
    bsz, l = x.shape[0], x.shape[1]
    xh = xs.reshape(bsz, l, nh, hp)
    xt = xh.astype(ACC_DTYPE) * dt[..., None]
    a = dt * A
    y, _ = ssd_chunked(xt, a, B, C, cfg.ssm_chunk)
    y = y + params["d_skip"][:, None] * xh.astype(ACC_DTYPE)
    y = y.reshape(bsz, l, di).astype(x.dtype)
    y = rms_norm(y * silu(z), params["gate_norm"], cfg.rms_eps)
    return lora_dense(y, params["out_proj"], maybe_lora(lora, "out_proj"),
                      cfg.lora.scale, use_kernel=use_lora_kernel)


# ---------------------------------------------------------------------------
# Decode (recurrent, O(1) per token)
# ---------------------------------------------------------------------------


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    di, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads
    hp = cfg.ssm_head_dim
    return {
        "h": jnp.zeros((batch, nh, hp, ns), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, di + 2 * ns), dtype),
    }


def mamba_decode(params: Params, lora: Optional[Params], x: jax.Array,
                 cache: Dict[str, jax.Array], cfg: ModelConfig
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B,1,d) -> (y: (B,1,d), new cache)."""
    di, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads
    hp = cfg.ssm_head_dim
    proj = lora_dense(x, params["in_proj"], maybe_lora(lora, "in_proj"),
                      cfg.lora.scale)
    z, xs, B, C, dt_raw = jnp.split(
        proj, [di, 2 * di, 2 * di + ns, 2 * di + 2 * ns], axis=-1)
    xbc_t = jnp.concatenate([xs, B, C], -1)              # (B,1,conv_ch)
    conv_in = jnp.concatenate([cache["conv"], xbc_t], axis=1)
    w = params["conv_w"]
    out = sum(conv_in[:, i:i + 1] * w[i] for i in range(w.shape[0]))
    xbc = silu(out + params["conv_b"])                   # (B,1,conv_ch)
    new_conv = conv_in[:, 1:]
    xs, B, C = jnp.split(xbc, [di, di + ns], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])[:, 0]
    A = -jnp.exp(params["a_log"])
    a = jnp.exp(dt * A)                                  # (B,nh)
    xh = xs[:, 0].reshape(-1, nh, hp).astype(jnp.float32)
    xt = xh * dt[..., None]
    Bv, Cv = B[:, 0].astype(jnp.float32), C[:, 0].astype(jnp.float32)
    h = (cache["h"] * a[:, :, None, None]
         + jnp.einsum("bhp,bn->bhpn", xt, Bv))
    y = jnp.einsum("bhpn,bn->bhp", h, Cv) + params["d_skip"][:, None] * xh
    y = y.reshape(-1, 1, di).astype(x.dtype)
    y = rms_norm(y * silu(z), params["gate_norm"], cfg.rms_eps)
    y = lora_dense(y, params["out_proj"], maybe_lora(lora, "out_proj"),
                   cfg.lora.scale)
    return y, {"h": h, "conv": new_conv}
