from repro.models import attention, blocks, common, mamba, mlp, model, moe  # noqa: F401
