"""Expert-parallel MoE via shard_map + all_to_all — the TPU-native dispatch.

GSPMD cannot shard a data-dependent scatter across expert shards (it
replicates the dispatch, which the dry-run exposed as ~100 TB/device of HBO
traffic for Kimi-K2). This module expresses the paper-relevant MoE layers
with explicit collectives instead:

  Layout ('ep_a2a'): expert weights sharded E over the data-parallel axes
  (EP) and FFN width f over 'model' (TP). Per layer:

    source shard --(all_to_all over dp)--> expert owner
      local capacity dispatch -> expert FFN on the f-slice
    expert owner --(all_to_all back)--> source shard
      combine with gates; one psum over 'model' merges the TP-partial
      down-projections (the shared expert folds into the same psum).

  Per-device weights for kimi-k2 (2x16x16): 384/32 experts x f/16 — ~4 GB of
  the 2 TB backbone: this is what makes the 1T config fit 16 GB HBM chips.

  Layout ('replicated'): small MoEs (granite-3b: ~3 GB of experts, 40
  experts indivisible by 16) replicate expert weights and dispatch purely
  locally per data shard — zero intra-MoE collectives.

Routing is computed identically on every TP column (activations are
replicated across 'model'), so each column runs the same a2a — see
EXPERIMENTS.md §Perf for the payload-slicing optimization over this.

Differentiable end-to-end (all_to_all/psum have transpose rules; scatter
indices are integer-valued and constant w.r.t. the tangent).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import shardctx
from repro.configs.base import ModelConfig
from repro.models.common import ACC_DTYPE, Params, silu
from repro.models.moe import group_capacity, ranks_within_groups

if hasattr(jax, "shard_map"):                      # jax >= 0.6
    _shard_map = jax.shard_map
else:                                              # 0.4.x: experimental home,
    from jax.experimental.shard_map import shard_map as _esm

    def _shard_map(fn, *, mesh, in_specs, out_specs, check_vma=True):
        return _esm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_rep=check_vma)           # check_vma was check_rep


def select_strategy(cfg: ModelConfig) -> Optional[str]:
    """Pick the distributed MoE layout for the active mesh (None => jnp/GSPMD
    path, used on CPU and single-device tests)."""
    if not shardctx.active() or not cfg.is_moe:
        return None
    dp = shardctx.axis_size("dp")
    tp = shardctx.axis_size("model")
    if dp > 1 and cfg.n_experts % dp == 0 and cfg.d_ff % tp == 0:
        return "ep_a2a"
    expert_bytes = (cfg.n_experts + cfg.n_shared_experts) * 3 \
        * cfg.d_model * cfg.d_ff * 2
    if expert_bytes <= 6e9:
        return "replicated"
    return None


def strategy_for_mesh(cfg: ModelConfig, mesh) -> Optional[str]:
    """Same decision from a mesh object (for sharding.param_specs)."""
    with shardctx.mesh_ctx(mesh):
        return select_strategy(cfg)


def _group_index(dp_axes: Tuple[str, ...], mesh) -> jax.Array:
    idx = jnp.zeros((), jnp.int32)
    for a in dp_axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _expert_ffn(buf, wg, wu, wd, dtype):
    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(dtype),
                   preferred_element_type=ACC_DTYPE).astype(dtype)
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(dtype),
                   preferred_element_type=ACC_DTYPE).astype(dtype)
    h = silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, wd.astype(dtype),
                      preferred_element_type=ACC_DTYPE).astype(dtype)


def _shared_ffn(xf, shared, dtype):
    sg = jnp.matmul(xf, shared["w_gate"].astype(dtype),
                    preferred_element_type=ACC_DTYPE).astype(dtype)
    su = jnp.matmul(xf, shared["w_up"].astype(dtype),
                    preferred_element_type=ACC_DTYPE).astype(dtype)
    return jnp.matmul(silu(sg) * su, shared["w_down"].astype(dtype),
                      preferred_element_type=ACC_DTYPE).astype(dtype)


def _route(xf, router, cfg):
    logits = jnp.matmul(xf.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss, local shard contribution
    me = jnp.mean(probs, axis=0)
    ce = jnp.bincount(idx[:, 0], length=cfg.n_experts
                      ).astype(jnp.float32) / xf.shape[0]
    aux = cfg.n_experts * jnp.sum(me * ce) * cfg.router_aux_coef
    return gates, idx, aux


# ---------------------------------------------------------------------------
# EP + a2a layout
# ---------------------------------------------------------------------------


def _local_moe_ep(x_blk, router, wg, wu, wd, shared, *, cfg: ModelConfig,
                  dp_axes, mesh):
    ep = 1
    for a in dp_axes:
        ep *= mesh.shape[a]
    e, k, d = cfg.n_experts, cfg.top_k, cfg.d_model
    eg = e // ep
    b_loc, s, _ = x_blk.shape
    t_loc = b_loc * s
    xf = x_blk.reshape(t_loc, d)
    dtype = x_blk.dtype

    gates, idx, aux = _route(xf, router, cfg)
    flat_e = idx.reshape(-1)                      # (n,) n = t_loc*k
    n = flat_e.shape[0]

    # ---- send-side packing by destination expert group --------------------
    dest = flat_e // eg
    cs = group_capacity(n, ep, cfg.capacity_factor)
    pos_s = ranks_within_groups(dest, ep)
    keep_s = pos_s < cs
    ps = jnp.where(keep_s, pos_s, 0)
    tok = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), k)
    payload = jnp.where(keep_s[:, None], xf[tok], 0).astype(dtype)
    send_x = jnp.zeros((ep, cs, d), dtype).at[dest, ps].add(payload)
    send_e = jnp.zeros((ep, cs), jnp.int32).at[dest, ps].add(
        jnp.where(keep_s, flat_e + 1, 0))         # 0 == empty slot

    # ---- the MoE all-to-all ------------------------------------------------
    recv_x = jax.lax.all_to_all(send_x, dp_axes, 0, 0, tiled=True)
    recv_e = jax.lax.all_to_all(send_e, dp_axes, 0, 0, tiled=True)

    # ---- receiver: dispatch to local experts ------------------------------
    g_idx = _group_index(dp_axes, mesh)
    rx = recv_x.reshape(ep * cs, d)
    re_ = recv_e.reshape(ep * cs) - 1
    le = re_ - g_idx * eg
    valid = re_ >= 0
    le_sort = jnp.where(valid, le, eg)            # invalid -> trash group
    cr = group_capacity(ep * cs, eg, cfg.capacity_factor)
    pos_r = ranks_within_groups(le_sort, eg + 1)
    keep_r = valid & (pos_r < cr)
    lec = jnp.where(keep_r, le, 0)
    pr = jnp.where(keep_r, pos_r, 0)
    buf = jnp.zeros((eg, cr, d), dtype).at[lec, pr].add(
        jnp.where(keep_r[:, None], rx, 0).astype(dtype))

    # ---- expert FFN on the local f-slice (TP partial) ----------------------
    y = _expert_ffn(buf, wg, wu, wd, dtype)

    # ---- return trip --------------------------------------------------------
    y_rows = jnp.where(keep_r[:, None], y[lec, pr], 0).reshape(ep, cs, d)
    back = jax.lax.all_to_all(y_rows, dp_axes, 0, 0, tiled=True)

    # ---- combine at the source ----------------------------------------------
    contrib = back[dest, ps] * (gates.reshape(-1)
                                * keep_s)[:, None].astype(dtype)
    out = jnp.zeros((t_loc, d), dtype).at[tok].add(contrib)
    if shared is not None:
        out = out + _shared_ffn(xf, shared, dtype)

    # merge TP-partial contributions (expert down-proj + shared down-proj)
    out = jax.lax.psum(out, "model")
    aux = jax.lax.pmean(aux, dp_axes)
    return out.reshape(b_loc, s, d), aux


# ---------------------------------------------------------------------------
# EP with broadcast tokens (decode with batch too small to shard, e.g.
# long_500k batch=1): tokens replicated; each device serves only its local
# expert slice; one psum over (dp + model) merges expert groups and TP.
# ---------------------------------------------------------------------------


def _local_moe_ep_bcast(x_blk, router, wg, wu, wd, shared, *,
                        cfg: ModelConfig, dp_axes, mesh):
    ep = 1
    for a in dp_axes:
        ep *= mesh.shape[a]
    e, k, d = cfg.n_experts, cfg.top_k, cfg.d_model
    eg = e // ep
    b, s, _ = x_blk.shape
    t = b * s
    xf = x_blk.reshape(t, d)
    dtype = x_blk.dtype
    gates, idx, aux = _route(xf, router, cfg)
    flat_e = idx.reshape(-1)
    n = flat_e.shape[0]
    g_idx = _group_index(dp_axes, mesh)
    le = flat_e - g_idx * eg
    mine = (le >= 0) & (le < eg)
    cr = group_capacity(n, eg, max(cfg.capacity_factor, float(eg)))
    le_sort = jnp.where(mine, le, eg)
    pos = ranks_within_groups(le_sort, eg + 1)
    keep = mine & (pos < cr)
    lec = jnp.where(keep, le, 0)
    pr = jnp.where(keep, pos, 0)
    tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    buf = jnp.zeros((eg, cr, d), dtype).at[lec, pr].add(
        jnp.where(keep[:, None], xf[tok], 0).astype(dtype))
    y = _expert_ffn(buf, wg, wu, wd, dtype)
    contrib = y[lec, pr] * (gates.reshape(-1) * keep)[:, None].astype(dtype)
    out = jnp.zeros((t, d), dtype).at[tok].add(contrib)
    if shared is not None:
        # every dp shard computes the same f-slice: pre-divide so the joint
        # psum over (dp, model) counts each f-slice exactly once
        out = out + _shared_ffn(xf, shared, dtype) / ep
    out = jax.lax.psum(out, tuple(dp_axes) + ("model",))
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Replicated-experts layout (small MoEs / indivisible expert counts)
# ---------------------------------------------------------------------------


def _local_moe_replicated(x_blk, router, wg, wu, wd, shared, *,
                          cfg: ModelConfig, dp_axes, mesh):
    from repro.models.moe import _capacity
    e, k, d = cfg.n_experts, cfg.top_k, cfg.d_model
    b_loc, s, _ = x_blk.shape
    t_loc = b_loc * s
    xf = x_blk.reshape(t_loc, d)
    dtype = x_blk.dtype
    gates, idx, aux = _route(xf, router, cfg)
    flat_e = idx.reshape(-1)
    cap = _capacity(t_loc, cfg)
    pos = ranks_within_groups(flat_e, e)
    keep = pos < cap
    pc = jnp.where(keep, pos, 0)
    tok = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), k)
    buf = jnp.zeros((e, cap, d), dtype).at[flat_e, pc].add(
        jnp.where(keep[:, None], xf[tok], 0).astype(dtype))
    y = _expert_ffn(buf, wg, wu, wd, dtype)
    contrib = y[flat_e, pc] * (gates.reshape(-1) * keep)[:, None].astype(dtype)
    out = jnp.zeros((t_loc, d), dtype).at[tok].add(contrib)
    if shared is not None:
        out = out + _shared_ffn(xf, shared, dtype)
    if dp_axes:
        aux = jax.lax.pmean(aux, dp_axes)
    return out.reshape(b_loc, s, d), aux


# ---------------------------------------------------------------------------
# Public entry
# ---------------------------------------------------------------------------


def moe_forward_dist(params: Params, lora: Optional[Params], x: jax.Array,
                     cfg: ModelConfig, strategy: str
                     ) -> Tuple[jax.Array, jax.Array]:
    """Distributed MoE layer. x: (B, S, d) GSPMD-sharded P(dp, None, None)."""
    mesh = shardctx.mesh()
    dp = shardctx.dp_axes()
    dp_size = shardctx.axis_size("dp")
    batch_shardable = x.shape[0] % dp_size == 0

    if strategy == "replicated" and not batch_shardable:
        # weights replicated anyway: plain jnp path is already correct
        from repro.models.moe import moe_forward
        return moe_forward(params, lora, x, cfg)

    if strategy == "ep_a2a":
        local = _local_moe_ep if batch_shardable else _local_moe_ep_bcast
        wspec = (P(dp, None, "model"), P(dp, None, "model"),
                 P(dp, "model", None))
        shared_spec = {"w_gate": P(None, "model"), "w_up": P(None, "model"),
                       "w_down": P("model", None)}
    else:
        # replicated experts: tokens shard over dp AND the (otherwise idle)
        # TP axis — without this every TP column redundantly computed the
        # same dispatch (measured 16x compute waste on granite; §Perf-4)
        tp = mesh.shape.get("model", 1)
        if x.shape[0] % (dp_size * tp) == 0:
            dp = tuple(dp) + ("model",)
        local = _local_moe_replicated
        wspec = (P(None, None, None),) * 3
        shared_spec = {"w_gate": P(None, None), "w_up": P(None, None),
                       "w_down": P(None, None)}
    fn = functools.partial(local, cfg=cfg, dp_axes=dp, mesh=mesh)

    xspec = P(dp, None, None) if batch_shardable else P(None, None, None)
    shared = params.get("shared")
    in_specs = (xspec, P(None, None), *wspec,
                shared_spec if shared is not None else None)
    out_specs = (xspec, P())

    mapped = _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False)
    out, aux = mapped(x, params["router"], params["w_gate"], params["w_up"],
                      params["w_down"], shared)

    if lora is not None:  # shared-path adapter (DESIGN.md), outside the map
        la = lora["out_adapter"]
        adapt = jnp.matmul(
            jnp.matmul(x, la["a"].astype(x.dtype),
                       preferred_element_type=ACC_DTYPE).astype(x.dtype),
            la["b"].astype(x.dtype), preferred_element_type=ACC_DTYPE)
        out = out + cfg.lora.scale * adapt.astype(x.dtype)
    return out, aux.astype(jnp.float32)
