"""GQA attention: init, training/prefill forward, cached decode.

Three score paths:
  * ``naive``   — full (Sq, Skv) score matrix; oracle for tests.
  * ``chunked`` — flash-style online softmax in pure jnp (lax.scan over KV
                  blocks, lax.map over Q blocks). O(S·block) memory; this is
                  the path the multi-pod dry-run lowers.
  * ``pallas``  — the TPU Pallas kernel in ``repro.kernels.flash_attention``
                  (validated in interpret mode on CPU).

Supports causal masking, sliding windows (SWA), GQA head grouping, RoPE,
qk-norm (Qwen3) and QKV bias (Qwen2).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (ACC_DTYPE, Params, apply_rope, dense_init,
                                 init_lora_pair, init_rms_norm, lora_dense,
                                 maybe_lora, rms_norm)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, q_dim, kv_dim = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d, q_dim, dtype),
        "wk": dense_init(ks[1], d, kv_dim, dtype),
        "wv": dense_init(ks[2], d, kv_dim, dtype),
        "wo": dense_init(ks[3], q_dim, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((q_dim,), dtype)
        p["bk"] = jnp.zeros((kv_dim,), dtype)
        p["bv"] = jnp.zeros((kv_dim,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(cfg.resolved_head_dim)
        p["k_norm"] = init_rms_norm(cfg.resolved_head_dim)
    return p


def init_attention_lora(key, cfg: ModelConfig) -> Params:
    r = cfg.lora.rank
    d, q_dim, kv_dim = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    out: Params = {}
    t = cfg.lora.targets
    ldt = jnp.dtype(cfg.lora.dtype)
    if "wq" in t:
        out["wq"] = init_lora_pair(ks[0], d, q_dim, r, ldt)
    if "wk" in t:
        out["wk"] = init_lora_pair(ks[1], d, kv_dim, r, ldt)
    if "wv" in t:
        out["wv"] = init_lora_pair(ks[2], d, kv_dim, r, ldt)
    if "wo" in t:
        out["wo"] = init_lora_pair(ks[3], q_dim, d, r, ldt)
    return out


# ---------------------------------------------------------------------------
# Score paths
# ---------------------------------------------------------------------------


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1)


def naive_attention(q, k, v, *, causal: bool, window: int,
                    q_positions, k_positions) -> jax.Array:
    """q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D). Oracle path."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(ACC_DTYPE),
                        k.astype(ACC_DTYPE)) / jnp.sqrt(float(d))
    mask = k_positions[:, None, :] <= q_positions[:, :, None]  # (B,Sq,Skv)
    if not causal:
        mask = jnp.ones_like(mask)
    if window:
        mask &= k_positions[:, None, :] > (q_positions[:, :, None] - window)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(ACC_DTYPE))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool, window: int,
                      q_positions, k_positions,
                      block_q: int = 512, block_k: int = 1024) -> jax.Array:
    """Flash-style online softmax, pure jnp. Same signature as naive."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    if sq <= block_q and skv <= block_k:
        return naive_attention(q, k, v, causal=causal, window=window,
                               q_positions=q_positions, k_positions=k_positions)

    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    qpos = jnp.pad(q_positions, ((0, 0), (0, pad_q)), constant_values=-1)
    kpos = jnp.pad(k_positions, ((0, 0), (0, pad_k)),
                   constant_values=2**30)  # padded keys masked out everywhere
    nq, nk = qp.shape[1] // block_q, kp.shape[1] // block_k

    # flash-style mixed precision: q/k/v stay in their storage dtype (bf16
    # in production) — only the per-block scores and the running (acc, m, l)
    # statistics live in f32. Halves attention HBM traffic vs upcasting.
    qb = qp.reshape(b, nq, block_q, hkv, group, d)
    kb = kp.reshape(b, nk, block_k, hkv, d)
    vb = vp.reshape(b, nk, block_k, hkv, d)
    qposb = qpos.reshape(b, nq, block_q)
    kposb = kpos.reshape(b, nk, block_k)
    scale = 1.0 / jnp.sqrt(float(d))

    def one_q_block(args):
        qi, qpos_i = args  # (b, block_q, hkv, g, d), (b, block_q)

        def kv_step(carry, kv):
            acc, m, l = carry
            ki, vi, kpos_i = kv
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, ki,
                           preferred_element_type=ACC_DTYPE) * scale
            mask = kpos_i[:, None, :] <= qpos_i[:, :, None]
            if not causal:
                mask = kpos_i[:, None, :] < 2**30
            if window:
                mask &= kpos_i[:, None, :] > (qpos_i[:, :, None] - window)
            s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vi.dtype), vi,
                preferred_element_type=ACC_DTYPE)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, hkv, group, block_q, d), ACC_DTYPE)
        m0 = jnp.full((b, hkv, group, block_q), NEG_INF, ACC_DTYPE)
        l0 = jnp.zeros((b, hkv, group, block_q), ACC_DTYPE)
        (acc, _, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
             kposb.transpose(1, 0, 2)))
        out = acc / jnp.maximum(l[..., None], 1e-20)
        return out.transpose(0, 3, 1, 2, 4)  # (b, block_q, hkv, g, d)

    out = jax.lax.map(one_q_block,
                      (qb.transpose(1, 0, 2, 3, 4, 5),
                       qposb.transpose(1, 0, 2)))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * block_q, hq, d)
    return out[:, :sq].astype(q.dtype)


def attention_scores(q, k, v, *, impl: str, causal: bool, window: int,
                     q_positions, k_positions) -> jax.Array:
    if impl == "naive":
        return naive_attention(q, k, v, causal=causal, window=window,
                               q_positions=q_positions, k_positions=k_positions)
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 q_positions=q_positions, k_positions=k_positions)
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops
        return kernel_ops.flash_attention(q, k, v, causal=causal, window=window,
                                          q_positions=q_positions,
                                          k_positions=k_positions)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# Forward (train / prefill) and cached decode
# ---------------------------------------------------------------------------


def attention_forward(params: Params, lora: Optional[Params], x: jax.Array,
                      cfg: ModelConfig, *, positions: jax.Array,
                      impl: str = "chunked",
                      use_lora_kernel: bool = False
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence attention. Returns (out, {"k","v"} post-RoPE for cache)."""
    scale = cfg.lora.scale
    q = lora_dense(x, params["wq"], maybe_lora(lora, "wq"), scale,
                   params.get("bq"), use_kernel=use_lora_kernel)
    k = lora_dense(x, params["wk"], maybe_lora(lora, "wk"), scale,
                   params.get("bk"), use_kernel=use_lora_kernel)
    v = lora_dense(x, params["wv"], maybe_lora(lora, "wv"), scale,
                   params.get("bv"), use_kernel=use_lora_kernel)
    from repro.shardctx import constrain
    q = _split_heads(q, cfg.n_heads)
    k = _split_heads(k, cfg.n_kv_heads)
    v = _split_heads(v, cfg.n_kv_heads)
    # pin q-head sharding across the reshape boundary. (Tried and reverted:
    # forcing kv replication removed the per-block all-to-alls but cost +21%
    # total collective bytes — GSPMD's a2a plan was cheaper; §Perf-2 it.3.)
    q = constrain(q, "dp", None, "model", None)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.rms_eps)
        k = rms_norm(k, params["k_norm"], cfg.rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = attention_scores(q, k, v, impl=impl, causal=True,
                           window=cfg.sliding_window,
                           q_positions=positions, k_positions=positions)
    out = out.reshape(x.shape[0], x.shape[1], cfg.q_dim)
    out = lora_dense(out, params["wo"], maybe_lora(lora, "wo"), scale,
                     use_kernel=use_lora_kernel)
    return out, {"k": k, "v": v}


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype
                  ) -> Dict[str, jax.Array]:
    """Per-layer cache. SWA archs keep a ring buffer of ``window`` slots.

    ``cfg.kv_cache_dtype == 'int8'``: k/v stored int8 with one f32 scale per
    (slot, kv-head) — halves the resident decode footprint vs bf16."""
    slots = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, slots, cfg.n_kv_heads, cfg.resolved_head_dim)
    if cfg.kv_cache_dtype == "int8":
        sshape = (batch, slots, cfg.n_kv_heads, 1)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v_scale": jnp.zeros(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _quant_kv(x: jax.Array):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def _cache_abs_positions(t: jax.Array, slots: int, window: int, b: int
                         ) -> jax.Array:
    """(B, slots) absolute position held by each cache slot after the write
    at position(s) ``t`` (scalar or per-row (B,) vector).

    Linear cache: slot j holds position j (stale j > t masked causally).
    Ring (SWA):   slot j holds ``t - ((t - j) mod W)`` — valid iff >= 0.
    """
    j = jnp.arange(slots, dtype=jnp.int32)
    if window and window <= slots:
        tb = t[:, None] if t.ndim else jnp.broadcast_to(t, (b,))[:, None]
        abs_pos = tb - ((tb - j[None, :]) % slots)
        return jnp.where(abs_pos >= 0, abs_pos, 2**30)    # unwritten slots
    return jnp.broadcast_to(j, (b, slots))


def _write_kv(cache: Dict[str, jax.Array], k: jax.Array, v: jax.Array,
              slot: jax.Array, dtype, int8: bool
              ) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
    """Write one token per row at ``slot`` (scalar or (B,) vector).

    Returns (new_cache, dequantized k view, dequantized v view)."""
    new_cache: Dict[str, jax.Array] = {}
    if int8:
        kq, ks = _quant_kv(k)
        vq, vs = _quant_kv(v)
        entries = (("k", kq), ("v", vq), ("k_scale", ks), ("v_scale", vs))
    else:
        entries = (("k", k), ("v", v))
    for name, val in entries:
        if slot.ndim:                                      # per-row slots
            rows = jnp.arange(val.shape[0])
            new_cache[name] = cache[name].at[rows, slot].set(val[:, 0])
        else:
            new_cache[name] = jax.lax.dynamic_update_slice_in_dim(
                cache[name], val, slot, axis=1)
    if int8:
        k_cache = (new_cache["k"].astype(jnp.float32)
                   * new_cache["k_scale"]).astype(dtype)
        v_cache = (new_cache["v"].astype(jnp.float32)
                   * new_cache["v_scale"]).astype(dtype)
    else:
        k_cache, v_cache = new_cache["k"], new_cache["v"]
    return new_cache, k_cache, v_cache


def attention_decode(params: Params, lora: Optional[Params], x: jax.Array,
                     cache: Dict[str, jax.Array], cfg: ModelConfig, *,
                     t: jax.Array, use_lora_kernel: bool = False
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x: (B,1,d); t: int32 absolute position — a scalar
    (whole batch at one position) or a (B,) vector (continuous-batching
    serving: every row at its own position).

    Full cache: write at slot ``t``, attend over slots ``<= t``.
    Ring (SWA): write at ``t % W``; slot j holds absolute position
    ``t - ((t - j) mod W)`` — valid iff >= 0.
    """
    scale = cfg.lora.scale
    b = x.shape[0]
    t = jnp.asarray(t, jnp.int32)
    pos = t[:, None] if t.ndim else jnp.full((b, 1), t, jnp.int32)
    uk = use_lora_kernel
    q = lora_dense(x, params["wq"], maybe_lora(lora, "wq"), scale,
                   params.get("bq"), uk)
    k = lora_dense(x, params["wk"], maybe_lora(lora, "wk"), scale,
                   params.get("bk"), uk)
    v = lora_dense(x, params["wv"], maybe_lora(lora, "wv"), scale,
                   params.get("bv"), uk)
    q = _split_heads(q, cfg.n_heads)
    k = _split_heads(k, cfg.n_kv_heads)
    v = _split_heads(v, cfg.n_kv_heads)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.rms_eps)
        k = rms_norm(k, params["k_norm"], cfg.rms_eps)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    slots = cache["k"].shape[1]
    slot = (t % slots).astype(jnp.int32)
    new_cache, k_cache, v_cache = _write_kv(
        cache, k, v, slot, x.dtype, cfg.kv_cache_dtype == "int8")
    k_positions = _cache_abs_positions(t, slots, cfg.sliding_window, b)

    out = naive_attention(q, k_cache, v_cache, causal=True,
                          window=cfg.sliding_window,
                          q_positions=pos, k_positions=k_positions)
    out = out.reshape(b, 1, cfg.q_dim)
    out = lora_dense(out, params["wo"], maybe_lora(lora, "wo"), scale,
                     None, uk)
    return out, new_cache


def attention_prefill(params: Params, lora: Optional[Params], x: jax.Array,
                      cache: Dict[str, jax.Array], cfg: ModelConfig, *,
                      positions: jax.Array, use_lora_kernel: bool = False
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Cached multi-token prefill: one parallel pass over a prompt chunk.

    x: (B, C, d) chunk hidden states; ``positions``: (C,) absolute positions
    shared across the batch (chunks are fed in order, so the chunk occupies
    a contiguous position range). Writes the chunk's K/V into the cache
    (linear slot ``p``; ring slot ``p mod W`` — requires C <= slots so one
    chunk never overwrites itself) and attends over the WHOLE cache with
    the same masking semantics as ``attention_decode``, which is what makes
    chunk i see chunks < i. Returns (out (B, C, q_dim), new cache).
    """
    scale = cfg.lora.scale
    b, c, _ = x.shape
    pos = jnp.broadcast_to(positions[None, :], (b, c)).astype(jnp.int32)
    uk = use_lora_kernel
    q = lora_dense(x, params["wq"], maybe_lora(lora, "wq"), scale,
                   params.get("bq"), uk)
    k = lora_dense(x, params["wk"], maybe_lora(lora, "wk"), scale,
                   params.get("bk"), uk)
    v = lora_dense(x, params["wv"], maybe_lora(lora, "wv"), scale,
                   params.get("bv"), uk)
    q = _split_heads(q, cfg.n_heads)
    k = _split_heads(k, cfg.n_kv_heads)
    v = _split_heads(v, cfg.n_kv_heads)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.rms_eps)
        k = rms_norm(k, params["k_norm"], cfg.rms_eps)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    slots = cache["k"].shape[1]
    idx = (positions % slots).astype(jnp.int32)            # (C,)
    new_cache: Dict[str, jax.Array] = {}
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quant_kv(k)
        vq, vs = _quant_kv(v)
        entries = (("k", kq), ("v", vq), ("k_scale", ks), ("v_scale", vs))
    else:
        entries = (("k", k), ("v", v))
    for name, val in entries:
        new_cache[name] = cache[name].at[:, idx].set(val)
    if cfg.kv_cache_dtype == "int8":
        k_cache = (new_cache["k"].astype(jnp.float32)
                   * new_cache["k_scale"]).astype(x.dtype)
        v_cache = (new_cache["v"].astype(jnp.float32)
                   * new_cache["v_scale"]).astype(x.dtype)
    else:
        k_cache, v_cache = new_cache["k"], new_cache["v"]

    k_positions = _cache_abs_positions(positions[-1], slots,
                                       cfg.sliding_window, b)
    out = naive_attention(q, k_cache, v_cache, causal=True,
                          window=cfg.sliding_window,
                          q_positions=pos, k_positions=k_positions)
    out = out.reshape(b, c, cfg.q_dim)
    out = lora_dense(out, params["wo"], maybe_lora(lora, "wo"), scale,
                     None, uk)
    return out, new_cache
