"""Top-k mixture-of-experts MLP with capacity-based scatter/gather dispatch.

Dispatch is dropless-ish: per-expert capacity ``C = ceil(T*k/E * cf)``;
tokens beyond capacity are dropped (standard Switch/GShard semantics). The
dispatch is a scatter into an ``(E, C, d)`` buffer — NOT a one-hot matmul —
so compiled FLOPs reflect *active* compute (≈ T·k·3·d·ff), which is what the
roofline and the paper's cost model (active FLOPs for MoE) need.

Sharding: the expert axis of ``w_*`` is sharded over the ``model`` mesh axis;
tokens arrive batch-sharded over ``data``. GSPMD inserts the all-to-all at
the scatter/gather boundaries.

LoRA: per DESIGN.md, adapters sit on the shared (d -> d) path around the
expert block (adapting 40-384 experts per layer would defeat PEFT); shared
experts get standard SwiGLU adapters.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (ACC_DTYPE, Params, dense_init,
                                 init_lora_pair, lora_dense, maybe_lora, silu)
from repro.shardctx import axis_size, constrain


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 7)
    std = 1.0 / math.sqrt(d)
    p: Params = {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32) * std
                   ).astype(jnp.float32),  # router stays fp32 (standard)
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * std).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * std).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   / math.sqrt(f)).astype(dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks[4], d, fs, dtype),
            "w_up": dense_init(ks[5], d, fs, dtype),
            "w_down": dense_init(ks[6], fs, d, dtype),
        }
    return p


def init_moe_lora(key, cfg: ModelConfig) -> Params:
    r, d = cfg.lora.rank, cfg.d_model
    ldt = jnp.dtype(cfg.lora.dtype)
    return {"out_adapter": init_lora_pair(key, d, d, r, ldt)}


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def group_capacity(n_items: int, n_groups: int, cf: float) -> int:
    """Slots per group for n_items spread over n_groups, cf headroom."""
    c = int(math.ceil(n_items / n_groups * cf))
    return max(8, -(-c // 8) * 8)


def ranks_within_groups(groups: jax.Array, n_groups: int) -> jax.Array:
    """groups: (n,) int32 group ids -> within-group rank (original order),
    via stable sort: O(n log n), TPU-friendly (no (n, G) one-hot cumsum)."""
    n = groups.shape[0]
    order = jnp.argsort(groups, stable=True)
    sorted_g = groups[order]
    counts = jnp.bincount(groups, length=n_groups)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(n, dtype=jnp.int32) \
        - offsets[sorted_g].astype(jnp.int32)
    return jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)


def moe_forward(params: Params, lora: Optional[Params], x: jax.Array,
                cfg: ModelConfig, use_lora_kernel: bool = False
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(t, cfg)
    xf = x.reshape(t, d)

    logits = jnp.matmul(xf.astype(jnp.float32), params["router"])  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                     # (T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch eq. 4): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)                              # (E,)
    ce = jnp.bincount(idx[:, 0], length=e).astype(jnp.float32) / t
    aux = e * jnp.sum(me * ce) * cfg.router_aux_coef

    # --- dispatch: sort-based position assignment (within-expert rank) -----
    # (a (T*k, E) one-hot cumsum is O(T*k*E) and lowers to a quadratic
    # reduce-window; the sort is O(n log n) and is what TPU MoE runtimes do)
    flat_e = idx.reshape(-1)                                  # (T*k,)
    pos = ranks_within_groups(flat_e, e)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, 0)
    tok = jnp.repeat(jnp.arange(t), k)                        # (T*k,)

    dispatch = jnp.where(keep[:, None], xf[tok], 0).astype(x.dtype)
    dispatch = constrain(dispatch, "dp", None)                # (T*k, d)
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[flat_e, pos_c].add(dispatch, mode="drop")
    # expert-parallel when E divides the model axis (kimi: 384/16); otherwise
    # capacity-parallel (granite: 40 experts -> shard C, weights stay small)
    espec = ("model", None, None) if e % axis_size("model") == 0 \
        else (None, "model", None)
    buf = constrain(buf, *espec)

    # --- expert compute: (E, C, d) x (E, d, f) -----------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(x.dtype),
                   preferred_element_type=ACC_DTYPE).astype(x.dtype)
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(x.dtype),
                   preferred_element_type=ACC_DTYPE).astype(x.dtype)
    g = constrain(g, *espec[:2], None)
    u = constrain(u, *espec[:2], None)
    h = silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype),
                   preferred_element_type=ACC_DTYPE).astype(x.dtype)
    y = constrain(y, *espec)

    # --- combine ------------------------------------------------------------
    gathered = y[flat_e, pos_c]                               # (T*k, d)
    gathered = constrain(gathered, "dp", None)
    w = (gates.reshape(-1) * keep).astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[tok].add(gathered * w[:, None])
    out = constrain(out, "dp", None)

    if cfg.n_shared_experts:
        sp = params["shared"]
        sg = jnp.matmul(xf, sp["w_gate"].astype(x.dtype),
                        preferred_element_type=ACC_DTYPE).astype(x.dtype)
        su = jnp.matmul(xf, sp["w_up"].astype(x.dtype),
                        preferred_element_type=ACC_DTYPE).astype(x.dtype)
        out = out + jnp.matmul(silu(sg) * su, sp["w_down"].astype(x.dtype),
                               preferred_element_type=ACC_DTYPE).astype(x.dtype)

    out = out.reshape(b, s, d)
    if lora is not None:
        la = lora["out_adapter"]
        adapt = jnp.matmul(
            jnp.matmul(x, la["a"].astype(x.dtype),
                       preferred_element_type=ACC_DTYPE).astype(x.dtype),
            la["b"].astype(x.dtype), preferred_element_type=ACC_DTYPE)
        out = out + cfg.lora.scale * adapt.astype(x.dtype)
    return out, aux.astype(jnp.float32)
