"""SwiGLU MLP (dense archs) with LoRA adapters."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (Params, dense_init, init_lora_pair,
                                 lora_dense, maybe_lora, silu)


def init_mlp(key, cfg: ModelConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d, f, dtype),
        "w_up": dense_init(ks[1], d, f, dtype),
        "w_down": dense_init(ks[2], f, d, dtype),
    }


def init_mlp_lora(key, cfg: ModelConfig) -> Params:
    r, d, f = cfg.lora.rank, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    out: Params = {}
    ldt = jnp.dtype(cfg.lora.dtype)
    t = cfg.lora.targets
    if "w_gate" in t:
        out["w_gate"] = init_lora_pair(ks[0], d, f, r, ldt)
    if "w_up" in t:
        out["w_up"] = init_lora_pair(ks[1], d, f, r, ldt)
    if "w_down" in t:
        out["w_down"] = init_lora_pair(ks[2], f, d, r, ldt)
    return out


def mlp_forward(params: Params, lora: Optional[Params], x: jax.Array,
                cfg: ModelConfig, use_lora_kernel: bool = False) -> jax.Array:
    s = cfg.lora.scale
    g = lora_dense(x, params["w_gate"], maybe_lora(lora, "w_gate"), s,
                   use_kernel=use_lora_kernel)
    u = lora_dense(x, params["w_up"], maybe_lora(lora, "w_up"), s,
                   use_kernel=use_lora_kernel)
    return lora_dense(silu(g) * u, params["w_down"],
                      maybe_lora(lora, "w_down"), s,
                      use_kernel=use_lora_kernel)
