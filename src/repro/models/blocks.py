"""Per-family layer blocks (pre-norm residual), stacked for lax.scan."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models.common import Params, init_rms_norm, rms_norm

ATTN_FAMILIES = ("dense", "moe", "hybrid", "audio", "vlm")


def init_layer(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {"norm1": init_rms_norm(cfg.d_model)}
    if cfg.family == "ssm":
        p["mamba"] = mamba_mod.init_mamba(ks[0], cfg, dtype)
        return p
    p["attn"] = attn_mod.init_attention(ks[0], cfg, dtype)
    p["norm2"] = init_rms_norm(cfg.d_model)
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp_mod.init_mlp(ks[1], cfg, dtype)
    if cfg.family == "hybrid":
        p["mamba"] = mamba_mod.init_mamba(ks[2], cfg, dtype)
    return p


def init_layer_lora(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {}
    if cfg.family == "ssm":
        p["mamba"] = mamba_mod.init_mamba_lora(ks[0], cfg)
        return p
    p["attn"] = attn_mod.init_attention_lora(ks[0], cfg)
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe_lora(ks[1], cfg)
    else:
        p["mlp"] = mlp_mod.init_mlp_lora(ks[1], cfg)
    if cfg.family == "hybrid":
        p["mamba"] = mamba_mod.init_mamba_lora(ks[2], cfg)
    return p


def layer_forward(params: Params, lora: Optional[Params], x: jax.Array,
                  cfg: ModelConfig, *, positions: jax.Array,
                  impl: str = "chunked", use_lora_kernel: bool = False
                  ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence layer. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    lget = (lambda k: lora.get(k) if lora is not None else None)
    h = rms_norm(x, params["norm1"], cfg.rms_eps)
    if cfg.family == "ssm":
        x = x + mamba_mod.mamba_forward(params["mamba"], lget("mamba"), h, cfg,
                                        use_lora_kernel)
        return x, aux
    attn_out, _ = attn_mod.attention_forward(
        params["attn"], lget("attn"), h, cfg, positions=positions, impl=impl,
        use_lora_kernel=use_lora_kernel)
    if cfg.family == "hybrid":
        ssm_out = mamba_mod.mamba_forward(params["mamba"], lget("mamba"), h,
                                          cfg, use_lora_kernel)
        x = x + 0.5 * (attn_out + ssm_out)
    else:
        x = x + attn_out
    h2 = rms_norm(x, params["norm2"], cfg.rms_eps)
    if cfg.family == "moe":
        moe_out, aux = _moe_dispatch(params["moe"], lget("moe"), h2, cfg,
                                     use_lora_kernel)
        x = x + moe_out
    else:
        x = x + mlp_mod.mlp_forward(params["mlp"], lget("mlp"), h2, cfg,
                                    use_lora_kernel)
    return x, aux


def _moe_dispatch(params: Params, lora, h: jax.Array, cfg: ModelConfig,
                  use_lora_kernel: bool):
    """Route to the shard_map expert-parallel path when a mesh is active."""
    from repro.models import moe_shard_map
    strategy = moe_shard_map.select_strategy(cfg)
    if strategy is not None:
        return moe_shard_map.moe_forward_dist(params, lora, h, cfg, strategy)
    return moe_mod.moe_forward(params, lora, h, cfg, use_lora_kernel)


def init_layer_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    c: Params = {}
    if cfg.family != "ssm":
        c["kv"] = attn_mod.init_kv_cache(cfg, batch, max_len, dtype)
    if cfg.has_ssm:
        c["ssm"] = mamba_mod.init_ssm_cache(cfg, batch, dtype)
    return c


def layer_prefill(params: Params, lora: Optional[Params], x: jax.Array,
                  cache: Params, cfg: ModelConfig, *, positions: jax.Array,
                  use_lora_kernel: bool = False) -> Tuple[jax.Array, Params]:
    """Cache-writing multi-token prefill through a layer. x: (B,C,d);
    ``positions``: (C,) absolute positions of the chunk.

    Attention-only families — SSM-bearing configs carry cumulative
    recurrent state and go through the exact ``model.decode_scan`` path
    instead (dispatched at the model level).
    """
    lget = (lambda k: lora.get(k) if lora is not None else None)
    new_cache: Params = {}
    h = rms_norm(x, params["norm1"], cfg.rms_eps)
    attn_out, new_cache["kv"] = attn_mod.attention_prefill(
        params["attn"], lget("attn"), h, cache["kv"], cfg, positions=positions,
        use_lora_kernel=use_lora_kernel)
    x = x + attn_out
    h2 = rms_norm(x, params["norm2"], cfg.rms_eps)
    if cfg.family == "moe":
        moe_out, _ = _moe_dispatch(params["moe"], lget("moe"), h2, cfg,
                                   use_lora_kernel)
        x = x + moe_out
    else:
        x = x + mlp_mod.mlp_forward(params["mlp"], lget("mlp"), h2, cfg,
                                    use_lora_kernel)
    return x, new_cache


def layer_decode(params: Params, lora: Optional[Params], x: jax.Array,
                 cache: Params, cfg: ModelConfig, *, t: jax.Array,
                 use_lora_kernel: bool = False) -> Tuple[jax.Array, Params]:
    """One-token decode through a layer. x: (B,1,d)."""
    lget = (lambda k: lora.get(k) if lora is not None else None)
    new_cache: Params = {}
    h = rms_norm(x, params["norm1"], cfg.rms_eps)
    if cfg.family == "ssm":
        out, new_cache["ssm"] = mamba_mod.mamba_decode(
            params["mamba"], lget("mamba"), h, cache["ssm"], cfg)
        return x + out, new_cache
    attn_out, new_cache["kv"] = attn_mod.attention_decode(
        params["attn"], lget("attn"), h, cache["kv"], cfg, t=t,
        use_lora_kernel=use_lora_kernel)
    if cfg.family == "hybrid":
        ssm_out, new_cache["ssm"] = mamba_mod.mamba_decode(
            params["mamba"], lget("mamba"), h, cache["ssm"], cfg)
        x = x + 0.5 * (attn_out + ssm_out)
    else:
        x = x + attn_out
    h2 = rms_norm(x, params["norm2"], cfg.rms_eps)
    if cfg.family == "moe":
        moe_out, _ = _moe_dispatch(params["moe"], lget("moe"), h2, cfg,
                                   use_lora_kernel)
        x = x + moe_out
    else:
        x = x + mlp_mod.mlp_forward(params["mlp"], lget("mlp"), h2, cfg,
                                    use_lora_kernel)
    return x, new_cache
