"""Full model assembly: init, split-aware forward, loss, cached decode.

Parameters come in two trees:
  * ``frozen`` — the pre-trained backbone (never receives gradients);
  * ``lora``   — the trainable adapters (paper: only A/B matrices train).

Layer params are stacked along a leading ``n_layers`` axis and executed with
``jax.lax.scan`` (+ optional remat), which keeps the HLO size independent of
depth — essential for lowering the 61-layer / 1T-param configs.

Split learning support: ``forward_hidden(..., lo, hi)`` runs layers
``[lo, hi)`` only. ``lo == 0`` includes the embedding; ``hi == n_layers``
is the natural server end (final norm + LM head live with the loss).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.common import (ACC_DTYPE, Params, dtype_of, embed_init,
                                 init_rms_norm, rms_norm,
                                 softmax_cross_entropy)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> Params:
    """Full parameter tree {"frozen": ..., "lora": ...}."""
    dtype = dtype_of(cfg.dtype)
    k_embed, k_head, k_layers, k_lora = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    lora_keys = jax.random.split(k_lora, cfg.n_layers)
    layers = jax.vmap(lambda k: blocks.init_layer(k, cfg, dtype))(layer_keys)
    lora_layers = jax.vmap(lambda k: blocks.init_layer_lora(k, cfg))(lora_keys)
    frozen: Params = {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        frozen["head"] = embed_init(k_head, cfg.padded_vocab, cfg.d_model,
                                    dtype).T
    return {"frozen": frozen, "lora": {"layers": lora_layers}}


def abstract_params(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct tree — no allocation (dry-run path for 1T params)."""
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def slice_layers(tree: Params, lo: int, hi: int) -> Params:
    return jax.tree_util.tree_map(lambda x: x[lo:hi], tree)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def embed_inputs(frozen: Params, batch_inputs: jax.Array, cfg: ModelConfig
                 ) -> jax.Array:
    """tokens (B,S) int32 -> (B,S,d); or pass-through for 'embeds' mode."""
    if cfg.input_mode == "embeds":
        return batch_inputs.astype(dtype_of(cfg.dtype))
    return jnp.take(frozen["embed"], batch_inputs, axis=0)


def forward_hidden(frozen: Params, lora: Optional[Params], inputs: jax.Array,
                   cfg: ModelConfig, *, lo: int = 0, hi: Optional[int] = None,
                   positions: Optional[jax.Array] = None,
                   impl: str = "chunked", remat: bool = True,
                   use_lora_kernel: bool = False,
                   inputs_embedded: Optional[bool] = None,
                   lora_sliced: bool = False,
                   unroll: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """Run layers [lo, hi). By default ``lo==0`` means ``inputs`` are
    tokens/embeds and the embedding is applied; otherwise ``inputs`` are
    hidden states (smashed data). ``inputs_embedded=True`` forces the
    hidden-state interpretation (server stage at cut 0).
    Returns (hidden, aux_loss_sum)."""
    hi = cfg.n_layers if hi is None else hi
    if inputs_embedded is None:
        inputs_embedded = lo != 0
    if not inputs_embedded:
        x = embed_inputs(frozen, inputs, cfg)
    else:
        x = inputs
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2])

    layer_params = slice_layers(frozen["layers"], lo, hi)
    if lora is None:
        layer_lora = None
    elif lora_sliced:  # caller already holds exactly the [lo,hi) adapters
        layer_lora = lora["layers"]
    else:
        layer_lora = slice_layers(lora["layers"], lo, hi)

    from repro.shardctx import constrain

    def body(carry, scanned):
        x, aux = carry
        if layer_lora is not None:
            lp, ll = scanned
        else:
            lp, ll = scanned, None
        x = constrain(x, "dp", None, None)
        x, aux_l = blocks.layer_forward(lp, ll, x, cfg, positions=positions,
                                        impl=impl,
                                        use_lora_kernel=use_lora_kernel)
        return (x, aux + aux_l), None

    if remat:
        body = jax.checkpoint(body)

    carry = (x, jnp.zeros((), jnp.float32))
    if unroll:
        # python loop -> unrolled HLO: required for exact cost_analysis FLOPs
        # (XLA's HloCostAnalysis counts while-loop bodies once, ignoring the
        # trip count) — the dry-run/roofline path uses this.
        take = lambda tree, i: jax.tree_util.tree_map(lambda v: v[i], tree)
        for i in range(hi - lo):
            lp = take(layer_params, i)
            ll = take(layer_lora, i) if layer_lora is not None else None
            carry, _ = body(carry, (lp, ll) if ll is not None else lp)
        x, aux = carry
        return x, aux

    scanned = (layer_params, layer_lora) if layer_lora is not None else layer_params
    (x, aux), _ = jax.lax.scan(body, carry, scanned)
    return x, aux


def logits_from_hidden(frozen: Params, x: jax.Array, cfg: ModelConfig
                       ) -> jax.Array:
    x = rms_norm(x, frozen["final_norm"], cfg.rms_eps)
    head = frozen["head"] if not cfg.tie_embeddings else frozen["embed"].T
    logits = jnp.matmul(x, head.astype(x.dtype),
                        preferred_element_type=ACC_DTYPE)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask pad columns (elementwise => sharding-friendly, no gather)
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, -1e30)
    return logits


def forward_loss(frozen: Params, lora: Optional[Params], batch: Dict[str, Any],
                 cfg: ModelConfig, *, impl: str = "chunked",
                 remat: bool = True, use_lora_kernel: bool = False,
                 unroll: bool = False) -> jax.Array:
    inputs = batch["embeds"] if cfg.input_mode == "embeds" else batch["tokens"]
    x, aux = forward_hidden(frozen, lora, inputs, cfg, impl=impl, remat=remat,
                            use_lora_kernel=use_lora_kernel, unroll=unroll)
    logits = logits_from_hidden(frozen, x, cfg)
    return softmax_cross_entropy(logits, batch["labels"]) + aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dtype = dtype_of(cfg.dtype)
    one = blocks.init_layer_cache(cfg, batch, max_len, dtype)
    # stack along a leading n_layers axis for lax.scan over layers
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype), one)


def decode_step(frozen: Params, lora: Optional[Params], cache: Params,
                inputs: jax.Array, t: jax.Array, cfg: ModelConfig,
                *, unroll: bool = False, use_lora_kernel: bool = False
                ) -> Tuple[jax.Array, Params]:
    """One token for the whole stack. inputs: (B,1) tokens or (B,1,d) embeds;
    t: int32 position — scalar (lock-step batch) or (B,) vector (continuous
    batching: each row decodes at its own position). Returns
    (logits (B,vocab), new cache)."""
    x = embed_inputs(frozen, inputs, cfg)

    def body(x, scanned):
        if lora is not None:
            lp, ll, lc = scanned
        else:
            (lp, lc), ll = scanned, None
        x, new_c = blocks.layer_decode(lp, ll, x, lc, cfg, t=t,
                                       use_lora_kernel=use_lora_kernel)
        return x, new_c

    if unroll:
        take = lambda tree, i: jax.tree_util.tree_map(lambda v: v[i], tree)
        new_caches = []
        for i in range(cfg.n_layers):
            lp = take(frozen["layers"], i)
            ll = take(lora["layers"], i) if lora is not None else None
            lc = take(cache, i)
            x, nc = body(x, (lp, ll, lc) if lora is not None else (lp, lc))
            new_caches.append(nc)
        new_cache = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), *new_caches)
    else:
        scanned = ((frozen["layers"], lora["layers"], cache)
                   if lora is not None else (frozen["layers"], cache))
        x, new_cache = jax.lax.scan(body, x, scanned)
    logits = logits_from_hidden(frozen, x, cfg)
    return logits[:, 0], new_cache


def decode_scan(frozen: Params, lora: Optional[Params], cache: Params,
                tokens: jax.Array, t0: jax.Array, cfg: ModelConfig,
                *, use_lora_kernel: bool = False) -> Tuple[jax.Array, Params]:
    """Consume C tokens with C sequential ``decode_step``s in ONE jitted
    call — bit-identical to the token-by-token host loop, so it is valid
    for every family including cumulative-state SSM/hybrid. tokens:
    (B, C) int32; t0: scalar int32 position of tokens[:, 0]. Returns
    (logits after the last token (B, vocab), new cache)."""
    c = tokens.shape[1]
    t0 = jnp.asarray(t0, jnp.int32)

    def body(carry, inp):
        cache, _ = carry
        tok, i = inp
        logits, cache = decode_step(frozen, lora, cache, tok, t0 + i, cfg,
                                    use_lora_kernel=use_lora_kernel)
        return (cache, logits), None

    xs = (jnp.moveaxis(tokens, 1, 0)[:, :, None],          # (C, B, 1)
          jnp.arange(c, dtype=jnp.int32))
    zero_logits = jnp.zeros((tokens.shape[0], cfg.padded_vocab), ACC_DTYPE)
    (cache, logits), _ = jax.lax.scan(body, (cache, zero_logits), xs)
    return logits, cache


def prefill_chunk(frozen: Params, lora: Optional[Params], cache: Params,
                  tokens: jax.Array, t0: jax.Array, cfg: ModelConfig,
                  *, use_lora_kernel: bool = False
                  ) -> Tuple[jax.Array, Params]:
    """Parallel multi-token prefill against the decode cache: one forward
    over a C-token chunk that writes K/V where ``decode_step`` would have,
    position by position. tokens: (B, C) int32; t0: scalar int32 position
    of tokens[:, 0]. Returns (last-position logits (B, vocab), new cache).

    Attention families only — SSM/hybrid cumulative state cannot be
    written in parallel; use ``decode_scan`` there (exact, still one
    jitted call per chunk).
    """
    if cfg.has_ssm:
        raise ValueError(
            f"prefill_chunk does not support family={cfg.family!r} "
            "(cumulative SSM state); use decode_scan")
    x = embed_inputs(frozen, tokens, cfg)
    positions = jnp.asarray(t0, jnp.int32) + jnp.arange(tokens.shape[1],
                                                        dtype=jnp.int32)

    def body(x, scanned):
        if lora is not None:
            lp, ll, lc = scanned
        else:
            (lp, lc), ll = scanned, None
        x, new_c = blocks.layer_prefill(lp, ll, x, lc, cfg,
                                        positions=positions,
                                        use_lora_kernel=use_lora_kernel)
        return x, new_c

    scanned = ((frozen["layers"], lora["layers"], cache)
               if lora is not None else (frozen["layers"], cache))
    x, new_cache = jax.lax.scan(body, x, scanned)
    logits = logits_from_hidden(frozen, x[:, -1:], cfg)
    return logits[:, 0], new_cache


def prefill(frozen: Params, lora: Optional[Params], inputs: jax.Array,
            cfg: ModelConfig, *, impl: str = "chunked", remat: bool = False,
            unroll: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Prefill forward: returns (last-position logits, full hidden).

    Note: cache population during prefill reuses the per-layer k/v returned
    by attention; for the dry-run we lower the compute-dominant path
    (hidden + logits), matching vLLM-style chunked prefill cost.
    """
    x, _ = forward_hidden(frozen, lora, inputs, cfg, impl=impl, remat=remat,
                          unroll=unroll)
    logits = logits_from_hidden(frozen, x[:, -1:], cfg)
    return logits[:, 0], x
