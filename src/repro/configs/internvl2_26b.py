"""internvl2-26b [vlm] — InternViT + InternLM2 [arXiv:2404.16821].
LM backbone (InternLM2-20B shape) only; ViT frontend stubbed: input_specs()
provides precomputed patch embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92553, head_dim=128,
    input_mode="embeds",
    source="arXiv:2404.16821 (InternVL2-26B)",
)
