"""hymba-1.5b [hybrid] — parallel attention + mamba heads [arXiv:2411.13676].
Simplified per DESIGN.md: every layer fuses SWA attention and an SSM branch."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    sliding_window=1024,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    source="arXiv:2411.13676 (Hymba)",
)
