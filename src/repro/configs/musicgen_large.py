"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284]. Frontend (EnCodec) is stubbed: input_specs() provides
precomputed frame embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048, head_dim=64,
    input_mode="embeds",
    source="arXiv:2306.05284 (MusicGen large)",
)
