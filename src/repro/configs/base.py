"""Config system: model architecture configs + input shapes + registry.

Every assigned architecture gets a module ``src/repro/configs/<id>.py``
exposing ``CONFIG``. The registry resolves ``--arch <id>`` strings.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LoRAConfig:
    rank: int = 16
    alpha: float = 32.0
    # which projections carry adapters (paper: "within each transformer layer")
    targets: Tuple[str, ...] = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")
    dtype: str = "float32"  # adapters train in fp32; backbone stays bf16

    @property
    def scale(self) -> float:
        return self.alpha / self.rank


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. ``family`` picks the layer type:

    dense  - GQA transformer decoder (RoPE / SwiGLU)
    moe    - GQA attention + top-k mixture-of-experts MLP
    ssm    - Mamba2 (SSD) attention-free blocks
    hybrid - parallel attention + Mamba heads per layer (Hymba)
    audio  - dense decoder over precomputed codec-frame embeddings (stub frontend)
    vlm    - dense decoder over precomputed patch embeddings (stub frontend)
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int            # query heads (0 for pure SSM)
    n_kv_heads: int
    d_ff: int               # dense MLP width; for moe: per-expert width
    vocab_size: int
    head_dim: int = 0       # 0 => d_model // n_heads
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 => full causal; >0 => SWA width
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    # frontend stub: 'tokens' (embedding lookup) or 'embeds' (precomputed)
    input_mode: str = "tokens"
    # serving: 'model' (=cfg.dtype) or 'int8' (paper's phi-compression idea
    # applied to the resident KV cache: halves decode HBM at rest)
    kv_cache_dtype: str = "model"
    # norm/misc
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    lora: LoRAConfig = field(default_factory=LoRAConfig)
    source: str = ""        # citation for the assigned config

    # ---- derived ---------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding/head param rows padded to a 256 multiple so the vocab
        dim shards cleanly (odd vocabs like 92553 otherwise force GSPMD to
        shard d_model and all-reduce full partial logits — §Perf-2)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.family == "moe"

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model if self.has_ssm else 0

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim if self.has_ssm else 0

    # ---- parameter counts (analytic; used by the cost model & roofline) ---
    def attn_params_per_layer(self) -> int:
        if self.is_attention_free:
            return 0
        d, q, kv = self.d_model, self.q_dim, self.kv_dim
        p = d * q + 2 * d * kv + q * d
        if self.qkv_bias:
            p += q + 2 * kv
        if self.qk_norm:
            p += 2 * self.resolved_head_dim
        return p

    def mlp_params_per_layer(self) -> int:
        d = self.d_model
        if self.is_moe:
            per_expert = 3 * d * self.d_ff
            total = (self.n_experts + self.n_shared_experts) * per_expert
            total += d * self.n_experts  # router
            return total
        if self.family == "ssm":
            di, ns = self.ssm_d_inner, self.ssm_state
            nh = self.ssm_n_heads
            # in_proj -> (z, x, B, C, dt), conv, dt/A/D, out_proj
            in_proj = d * (2 * di + 2 * ns + nh)
            conv = self.ssm_conv_width * (di + 2 * ns)
            extra = 2 * nh + nh  # A_log, D, dt_bias
            out_proj = di * d
            return in_proj + conv + extra + out_proj + di  # + gate norm
        return 3 * d * self.d_ff

    def ssm_params_per_layer(self) -> int:
        if self.family != "hybrid":
            return 0
        di, ns, nh = self.ssm_d_inner, self.ssm_state, self.ssm_n_heads
        in_proj = self.d_model * (2 * di + 2 * ns + nh)
        conv = self.ssm_conv_width * (di + 2 * ns)
        return in_proj + conv + 3 * nh + di * self.d_model + di

    def params_per_layer(self) -> int:
        norms = 2 * self.d_model
        return (self.attn_params_per_layer() + self.mlp_params_per_layer()
                + self.ssm_params_per_layer() + norms)

    def embed_params(self) -> int:
        p = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            p += self.vocab_size * self.d_model  # lm head
        p += self.d_model  # final norm
        return p

    def total_params(self) -> int:
        return self.n_layers * self.params_per_layer() + self.embed_params()

    def active_params(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.total_params()
        per_expert = 3 * self.d_model * self.d_ff
        inactive = (self.n_experts - self.top_k) * per_expert
        return self.total_params() - self.n_layers * inactive

    def lora_params_per_layer(self) -> int:
        r, d = self.lora.rank, self.d_model
        hd = self.resolved_head_dim
        total = 0
        t = self.lora.targets
        if not self.is_attention_free:
            if "wq" in t:
                total += r * (d + self.q_dim)
            if "wk" in t:
                total += r * (d + self.kv_dim)
            if "wv" in t:
                total += r * (d + self.kv_dim)
            if "wo" in t:
                total += r * (self.q_dim + d)
        if self.is_moe:
            # adapters on shared dims only (router stays frozen): per-expert
            # adapters would defeat PEFT; we adapt the expert-merged output via
            # a single (d,d) adapter pair per layer.
            total += 2 * r * d
        elif self.family == "ssm":
            di = self.ssm_d_inner
            total += r * (d + di) + r * (di + d)  # in/out proj adapters
        else:
            if "w_gate" in t:
                total += r * (d + self.d_ff)
            if "w_up" in t:
                total += r * (d + self.d_ff)
            if "w_down" in t:
                total += r * (self.d_ff + d)
        if self.family == "hybrid":
            di = self.ssm_d_inner
            total += r * (d + di) + r * (di + d)
        del hd
        return total

    # ---- reduced variant for CPU smoke tests ------------------------------
    def reduced(self) -> "ModelConfig":
        """Same family, tiny: <=2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        hd = 32
        n_heads = max(1, min(self.n_heads, 4)) if self.n_heads else 0
        n_kv = max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads else 0
        if n_heads and n_kv:
            n_heads = (n_heads // n_kv) * n_kv or n_kv
        return replace(
            self,
            n_layers=2,
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd if n_heads else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            # cf = E/k makes the reduced MoE dropless: worst-case per-expert
            # load is T (every token picks it), and cap = T*k/E * (E/k) = T.
            # Keeps teacher-forced forward == step-by-step decode in tests.
            capacity_factor=(min(self.n_experts, 4) / min(self.top_k, 2)
                             if self.n_experts else self.capacity_factor),
            n_shared_experts=min(self.n_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.has_ssm else self.ssm_head_dim,
            ssm_chunk=32 if self.has_ssm else self.ssm_chunk,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            dtype="float32",
            lora=replace(self.lora, rank=4, alpha=8.0),
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# Sliding window used for the long-context decode variant of attention archs.
LONG_CONTEXT_WINDOW = 8_192


def long_context_variant(cfg: ModelConfig) -> Optional[ModelConfig]:
    """Config variant used for long_500k, or None if the arch cannot run it.

    SSM archs run natively (constant state). Attention archs require the
    sliding-window variant (full attention at 524k is out of scope per spec);
    we return the SWA variant for them, which is a *different* (sub-quadratic)
    attention than their default.
    """
    if cfg.family == "ssm":
        return cfg
    window = cfg.sliding_window or LONG_CONTEXT_WINDOW
    return replace(cfg, sliding_window=min(window, LONG_CONTEXT_WINDOW))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "phi3-medium-14b",
    "qwen3-0.6b",
    "granite-moe-3b-a800m",
    "kimi-k2-1t-a32b",
    "mamba2-370m",
    "musicgen-large",
    "qwen3-4b",
    "hymba-1.5b",
    "internvl2-26b",
    "qwen2-7b",
    "llama32-1b",  # the paper's own simulation model (Sec. V)
)

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch]}")
    cfg = mod.CONFIG
    assert cfg.name == arch, (cfg.name, arch)
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def asdict(cfg: ModelConfig) -> dict:
    return dataclasses.asdict(cfg)
