"""llama32-1b — the paper's own simulation model: "a 1B LLaMA 3.2 model with
32-layer transformer decoders" (Sec. V-A, citing [14])."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama32-1b", family="dense",
    n_layers=32, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab_size=128256, head_dim=64,
    rope_theta=500_000.0, tie_embeddings=True,
    source="paper Sec. V-A / arXiv:2405.16406 [14]",
)
