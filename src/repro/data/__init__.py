from repro.data.pipeline import (DeviceDataset, make_fleet_datasets,
                                 synthetic_lm_task, batch_specs)  # noqa: F401
