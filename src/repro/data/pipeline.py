"""Data pipeline: per-device non-IID token streams (the paper's
"geo-distributed personal data"), deterministic and shardable.

The synthetic LM task is *learnable* (a noisy Markov chain per device with a
shared global transition structure), so SL fine-tuning convergence (Eq. 1)
is measurable: loss under the fine-tuned adapters must drop below the
frozen-backbone loss. For the 'embeds' frontends (audio/VLM) the pipeline
emits precomputed frame/patch embeddings per DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import InputShape, ModelConfig


def synthetic_lm_task(vocab: int, *, seed: int = 0, order_bias: float = 9.0
                      ) -> np.ndarray:
    """A global transition matrix shared by all devices (the 'task').

    The dominant structure is a *seeded successor permutation* — different
    seeds are genuinely different languages, so fine-tuning on a new seed is
    a real domain shift for the LoRA adapters (the paper's premise: a
    pre-trained LLM adapted to geo-distributed personal data)."""
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(vocab, vocab))
    idx = np.arange(vocab)
    successor = rng.permutation(vocab)
    logits[idx, successor] += order_bias
    p = np.exp(logits - logits.max(-1, keepdims=True))
    return p / p.sum(-1, keepdims=True)


@dataclasses.dataclass
class DeviceDataset:
    """D_m: local dataset of device m (Sec. II-A)."""
    device_id: int
    cfg: ModelConfig
    transition: np.ndarray
    size: int
    seed: int
    noise: float = 0.1         # device-specific label noise => non-IID

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def _sample_tokens(self, batch: int, seq_len: int) -> np.ndarray:
        v = self.transition.shape[0]
        out = np.empty((batch, seq_len + 1), np.int32)
        state = self._rng.integers(0, v, size=batch)
        out[:, 0] = state
        for t in range(1, seq_len + 1):
            u = self._rng.random(batch)
            cdf = np.cumsum(self.transition[state], axis=-1)
            state = (u[:, None] < cdf).argmax(-1)
            flip = self._rng.random(batch) < self.noise
            state = np.where(flip, self._rng.integers(0, v, batch), state)
            out[:, t] = state
        return out

    def minibatch(self, batch: int, seq_len: int) -> Dict[str, np.ndarray]:
        """H_{m,n}(t): one mini-batch draw (stage 3, device-side FP)."""
        toks = self._sample_tokens(batch, seq_len)
        ex: Dict[str, np.ndarray] = {
            "labels": toks[:, 1:].astype(np.int32)}
        if self.cfg.input_mode == "embeds":
            # stubbed modality frontend: deterministic embedding of tokens
            d = self.cfg.d_model
            emb_rng = np.random.default_rng(hash((self.seed, "frontend")) % 2**31)
            table = emb_rng.normal(size=(self.transition.shape[0], d)).astype(
                np.float32) * 0.02
            ex["embeds"] = table[toks[:, :-1]]
        else:
            ex["tokens"] = toks[:, :-1].astype(np.int32)
        return ex


def make_fleet_datasets(cfg: ModelConfig, n_devices: int, *, vocab: int = 0,
                        seed: int = 0, sizes: Optional[List[int]] = None
                        ) -> List[DeviceDataset]:
    v = vocab or min(cfg.vocab_size, 512)
    trans = synthetic_lm_task(v, seed=seed)
    sizes = sizes or [2000 + 500 * i for i in range(n_devices)]
    return [DeviceDataset(device_id=m, cfg=cfg, transition=trans,
                          size=sizes[m], seed=seed + 101 * (m + 1),
                          noise=0.05 + 0.03 * m)
            for m in range(n_devices)]


def batch_specs(cfg: ModelConfig, shape: InputShape, cut: int = 0):
    """ShapeDtypeStruct stand-ins for every model input (dry-run §2).
    ``cut > 0`` (train): the pod job is the SL *server side* — its input is
    the phi-compressed smashed data at the cut, not raw tokens."""
    import jax
    import jax.numpy as jnp

    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train" and cut > 0:
        return {"smashed": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if shape.kind in ("train", "prefill"):
        if cfg.input_mode == "embeds":
            inputs = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                     jnp.bfloat16)}
        else:
            inputs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if shape.kind == "train":
            inputs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return inputs
    # decode: one new token against a cache of seq_len
    if cfg.input_mode == "embeds":
        return {"embeds": jax.ShapeDtypeStruct((b, 1, cfg.d_model),
                                               jnp.bfloat16)}
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
