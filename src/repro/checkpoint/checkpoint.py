"""Pytree checkpointing: npz payload + JSON structure manifest.

Handles arbitrary nested dict/list/tuple trees of jnp arrays plus scalar
leaves. Restores onto the host; sharded restore re-shards via the caller's
``jax.device_put`` with the target sharding.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        items.append((key, leaf))
    return items, treedef


def save_checkpoint(path: str, tree, *, step: int = 0, extra: Dict = None
                    ) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    items, _ = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    # one transfer for all leaves instead of a per-leaf device sync
    host_leaves = jax.device_get([leaf for _, leaf in items])
    for i, ((key, _), host) in enumerate(zip(items, host_leaves,
                                             strict=True)):
        arr = np.asarray(host)
        name = f"leaf_{i}"
        # npz cannot hold bf16: store raw bits + dtype tag
        if arr.dtype == jax.numpy.bfloat16:
            arrays[name] = arr.view(np.uint16)
            manifest["leaves"].append({"key": key, "name": name,
                                       "dtype": "bfloat16"})
        else:
            arrays[name] = arr
            manifest["leaves"].append({"key": key, "name": name,
                                       "dtype": str(arr.dtype)})
    tmp = path + ".tmp"
    np.savez(tmp, **arrays)
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)


def load_checkpoint(path: str, tree_like) -> Tuple[Any, int]:
    """Restore into the structure of ``tree_like``. Returns (tree, step)."""
    with open(path + ".json") as f:
        manifest = json.load(f)
    data = np.load(path)
    by_key = {}
    for leaf in manifest["leaves"]:
        arr = data[leaf["name"]]
        if leaf["dtype"] == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16)
        by_key[leaf["key"]] = arr
    items, treedef = _flatten_with_paths(tree_like)
    leaves = []
    for key, like in items:
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = by_key[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {like.shape}")
        leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]
