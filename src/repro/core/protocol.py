"""The SL fine-tuning protocol — Sec. II-B stages 1-5, executed for real.

Each training round, for each participating device:

  Stage 1  LLM splitting: CARD (or a baseline policy) picks (c, f*) from the
           current channel state; adapters split into R^D / R^S.
  Stage 2  Device-side adapter distribution (accounted in Eq. 9).
  Stage 3  FP: device-side forward -> phi-compressed smashed data -> server FP.
  Stage 4  BP: server adapter update -> compressed gradient -> device update.
           (Stages 3-4 repeat for T local epochs.)
  Stage 5  Device-side adapter upload; server merges R = {R^D;R^S}.

The JAX computation is real (split_grads + optimizer); the wall-clock /
energy numbers are *simulated* through the paper's cost model driven by the
same workload constants — this is exactly the paper's methodology (a
physical 5-Jetson testbed feeding a delay/energy model).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import card as card_lib
from repro.core.channel import WirelessChannel
from repro.core.cost_model import RoundContext, Workload
from repro.core.faults import (CircuitBreaker, ExchangeFailed, FaultInjector,
                               RetryPolicy, retry_call)
from repro.core.hardware import DeviceProfile, SimParams
from repro.core.splitting import SplitExecutor
from repro.models.common import Params
from repro.optim import Optimizer, apply_updates

Policy = Callable[[RoundContext], card_lib.Decision]

POLICIES: Dict[str, Policy] = {
    "card": card_lib.card,
    "server_only": card_lib.server_only,
    "device_only": card_lib.device_only,
}


@dataclasses.dataclass
class RoundLog:
    """One (round, device) record of live split fine-tuning: the CARD
    decision (``cut`` layers, ``frequency`` Hz), its modeled ``delay`` in
    seconds and ``server_energy`` in joules, the measured training
    ``loss``, plus churn accounting (``status``/``attempts``/retry
    ``backoff_s``)."""
    round_idx: int
    device: str
    cut: int
    frequency: float
    delay: float
    server_energy: float
    loss: float
    cost: float
    # churn-tolerance accounting
    status: str = "ok"        # ok | dropped | evicted | absent | rolled_back
    attempts: int = 1         # exchange attempts (max over the round's epochs)
    backoff_s: float = 0.0    # retry backoff accumulated over the round


@dataclasses.dataclass
class RoundSummary:
    """Per-round aggregation outcome: how many devices were scheduled vs
    survived churn, and whether the quorum committed the adapter update."""
    round_idx: int
    attempted: int            # devices scheduled this round (member + closed)
    survived: int
    committed: bool           # quorum met -> adapter updates kept


@dataclasses.dataclass
class TrainResult:
    """Everything a fine-tuning run produced: the final LoRA params, the
    flat ``RoundLog`` stream, and per-round commit summaries; the mean_*
    helpers average surviving (``status == "ok"``) rounds only — delay in
    seconds, energy in joules."""
    lora: Params
    logs: List[RoundLog]
    round_summaries: List[RoundSummary] = dataclasses.field(
        default_factory=list)

    def mean_delay(self) -> float:
        return _nanmean_of([l.delay for l in self.logs if l.status == "ok"])

    def mean_energy(self) -> float:
        return _nanmean_of([l.server_energy for l in self.logs
                            if l.status == "ok"])

    def losses(self) -> List[float]:
        return [l.loss for l in self.logs if l.status == "ok"]

    def rounds_committed(self) -> int:
        if not self.round_summaries:
            return len({l.round_idx for l in self.logs})
        return sum(s.committed for s in self.round_summaries)


def _nanmean_of(vals: List[float]) -> float:
    arr = np.asarray(vals, np.float64)
    mask = ~np.isnan(arr)
    return float(arr[mask].mean()) if mask.any() else float("nan")


class SplitFineTuner:
    """Runs the full protocol over a device fleet."""

    def __init__(self, cfg: ModelConfig, frozen: Params, lora: Params,
                 optimizer: Optimizer, *, devices: List[DeviceProfile],
                 server: DeviceProfile, channels: List[WirelessChannel],
                 datasets: List, sim: SimParams, policy: str = "card",
                 static_cut: Optional[int] = None, compress: bool = True,
                 cost_cfg: Optional[ModelConfig] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 quorum: float = 0.5,
                 sleep: Optional[Callable[[float], None]] = None):
        assert len(devices) == len(channels) == len(datasets)
        if not 0.0 <= quorum <= 1.0:
            raise ValueError(f"quorum must be in [0, 1], got {quorum!r}")
        self.cfg = cfg
        # delay/energy accounting may use the FULL-SIZE config while the
        # actual JAX training runs the reduced one (paper methodology:
        # measured testbed feeding an analytic model)
        self.cost_cfg = cost_cfg or cfg
        self.frozen = frozen
        self.lora = lora
        self.optimizer = optimizer
        self.opt_state = optimizer.init(lora)
        self.devices = devices
        self.server = server
        self.channels = channels
        self.datasets = datasets
        self.sim = sim
        self.policy_name = policy
        self.static_cut = static_cut
        self.executor = SplitExecutor(cfg, compress=compress)
        self.rng = np.random.default_rng(7)
        # churn tolerance: injected link faults, retry policy for the
        # activation/gradient exchange, repeat-offender eviction, and the
        # minimum fraction of scheduled devices a round needs to commit
        self.fault_injector = fault_injector
        self.retry_policy = retry_policy or RetryPolicy()
        self.breaker = breaker or CircuitBreaker()
        self.quorum = quorum
        self._sleep = sleep  # None = account backoff without wall-clock sleep

    def _decide(self, ctx: RoundContext) -> card_lib.Decision:
        if self.policy_name == "static":
            assert self.static_cut is not None
            return card_lib.static_cut(ctx, self.static_cut)
        if self.policy_name == "random":
            return card_lib.random_cut(ctx, self.rng)
        return POLICIES[self.policy_name](ctx)

    def _exchange(self, n: int, device_idx: int, fn: Callable[[], object]):
        """One activation/gradient exchange under timeout + capped
        exponential-backoff retries; injected link faults fail attempts.
        Returns ``(result, attempts, backoff_s)``; raises
        :class:`ExchangeFailed` when the retry budget is exhausted."""
        attempt_counter = [0]

        def attempt():
            attempt_counter[0] += 1
            if self.fault_injector is not None:
                self.fault_injector.check(n, device_idx, attempt_counter[0])
            return fn()

        return retry_call(attempt, self.retry_policy, sleep=self._sleep)

    def run_round(self, n: int, device_idx: int) -> RoundLog:
        """One device's round; raises :class:`ExchangeFailed` if the link
        stays down past the retry budget (caller restores state)."""
        dev = self.devices[device_idx]
        chan_state = self.channels[device_idx].draw()
        workload = Workload(self.cost_cfg, self.sim.mini_batch,
                            self.sim.seq_len)
        ctx = RoundContext(workload=workload, device=dev, server=self.server,
                           channel=chan_state, sim=self.sim)
        # Stage 1: splitting decision (cut index mapped onto the trained
        # stack if the cost model uses the full-size config)
        decision = self._decide(ctx)
        cut = decision.cut
        if self.cost_cfg.n_layers != self.cfg.n_layers:
            cut = round(cut * self.cfg.n_layers / self.cost_cfg.n_layers)

        # Stages 2-5: T local epochs of real split training; each epoch's
        # smashed-data/gradient exchange runs under the retry envelope.
        # Only the last epoch's loss is logged, so the device sync happens
        # once after the loop instead of serializing every epoch.
        loss = None
        attempts = 1
        backoff_s = 0.0
        for _ in range(self.sim.local_epochs):
            batch = self.datasets[device_idx].minibatch(
                self.sim.mini_batch, self.sim.seq_len)
            (loss, grads), tries, waited_s = self._exchange(
                n, device_idx,
                lambda b=batch: self.executor.step(self.frozen, self.lora,
                                                   b, cut))
            attempts = max(attempts, tries)
            backoff_s += waited_s
            updates, self.opt_state = self.optimizer.update(
                grads, self.opt_state, self.lora)
            self.lora = apply_updates(self.lora, updates)
        loss_val = float(loss) if loss is not None else float("nan")

        return RoundLog(round_idx=n, device=dev.name, cut=cut,
                        frequency=decision.frequency,
                        delay=decision.delay + backoff_s,
                        server_energy=decision.energy, loss=loss_val,
                        cost=decision.cost, attempts=attempts,
                        backoff_s=backoff_s)

    def _skip_log(self, n: int, device_idx: int, status: str,
                  attempts: int = 0, backoff_s: float = 0.0) -> RoundLog:
        nan = float("nan")
        return RoundLog(round_idx=n, device=self.devices[device_idx].name,
                        cut=-1, frequency=nan, delay=nan, server_energy=nan,
                        loss=nan, cost=nan, status=status, attempts=attempts,
                        backoff_s=backoff_s)

    def run(self, n_rounds: int) -> TrainResult:
        """Run the protocol with graceful degradation: a round commits with
        any quorum of surviving devices; below quorum its adapter updates
        are rolled back (the fleet keeps going either way)."""
        logs: List[RoundLog] = []
        summaries: List[RoundSummary] = []
        for n in range(n_rounds):
            round_state = (self.lora, self.opt_state)
            round_logs: List[RoundLog] = []
            attempted = 0
            survived = 0
            for m in range(len(self.devices)):
                if self.fault_injector is not None \
                        and not self.fault_injector.is_member(n, m):
                    round_logs.append(self._skip_log(n, m, "absent"))
                    continue
                if not self.breaker.allow(m, n):
                    round_logs.append(self._skip_log(n, m, "evicted"))
                    continue
                attempted += 1
                device_state = (self.lora, self.opt_state)
                try:
                    round_logs.append(self.run_round(n, m))
                    self.breaker.record_success(m)
                    survived += 1
                except ExchangeFailed as e:
                    # discard the device's partial round, penalize repeats
                    self.lora, self.opt_state = device_state
                    self.breaker.record_failure(m, n)
                    round_logs.append(self._skip_log(
                        n, m, "dropped", attempts=e.attempts,
                        backoff_s=e.backoff_s))
            needed = max(1, math.ceil(self.quorum * attempted)) \
                if attempted else 1
            committed = survived >= needed
            if not committed:
                self.lora, self.opt_state = round_state
                for rl in round_logs:
                    if rl.status == "ok":
                        rl.status = "rolled_back"
            logs.extend(round_logs)
            summaries.append(RoundSummary(round_idx=n, attempted=attempted,
                                          survived=survived,
                                          committed=committed))
        return TrainResult(lora=self.lora, logs=logs,
                           round_summaries=summaries)
