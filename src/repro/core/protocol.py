"""The SL fine-tuning protocol — Sec. II-B stages 1-5, executed for real.

Each training round, for each participating device:

  Stage 1  LLM splitting: CARD (or a baseline policy) picks (c, f*) from the
           current channel state; adapters split into R^D / R^S.
  Stage 2  Device-side adapter distribution (accounted in Eq. 9).
  Stage 3  FP: device-side forward -> phi-compressed smashed data -> server FP.
  Stage 4  BP: server adapter update -> compressed gradient -> device update.
           (Stages 3-4 repeat for T local epochs.)
  Stage 5  Device-side adapter upload; server merges R = {R^D;R^S}.

The JAX computation is real (split_grads + optimizer); the wall-clock /
energy numbers are *simulated* through the paper's cost model driven by the
same workload constants — this is exactly the paper's methodology (a
physical 5-Jetson testbed feeding a delay/energy model).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import card as card_lib
from repro.core.channel import WirelessChannel
from repro.core.cost_model import RoundContext, Workload
from repro.core.hardware import DeviceProfile, SimParams
from repro.core.splitting import SplitExecutor
from repro.models.common import Params
from repro.optim import Optimizer, apply_updates

Policy = Callable[[RoundContext], card_lib.Decision]

POLICIES: Dict[str, Policy] = {
    "card": card_lib.card,
    "server_only": card_lib.server_only,
    "device_only": card_lib.device_only,
}


@dataclasses.dataclass
class RoundLog:
    round_idx: int
    device: str
    cut: int
    frequency: float
    delay: float
    server_energy: float
    loss: float
    cost: float


@dataclasses.dataclass
class TrainResult:
    lora: Params
    logs: List[RoundLog]

    def mean_delay(self) -> float:
        return float(np.mean([l.delay for l in self.logs]))

    def mean_energy(self) -> float:
        return float(np.mean([l.server_energy for l in self.logs]))

    def losses(self) -> List[float]:
        return [l.loss for l in self.logs]


class SplitFineTuner:
    """Runs the full protocol over a device fleet."""

    def __init__(self, cfg: ModelConfig, frozen: Params, lora: Params,
                 optimizer: Optimizer, *, devices: List[DeviceProfile],
                 server: DeviceProfile, channels: List[WirelessChannel],
                 datasets: List, sim: SimParams, policy: str = "card",
                 static_cut: Optional[int] = None, compress: bool = True,
                 cost_cfg: Optional[ModelConfig] = None):
        assert len(devices) == len(channels) == len(datasets)
        self.cfg = cfg
        # delay/energy accounting may use the FULL-SIZE config while the
        # actual JAX training runs the reduced one (paper methodology:
        # measured testbed feeding an analytic model)
        self.cost_cfg = cost_cfg or cfg
        self.frozen = frozen
        self.lora = lora
        self.optimizer = optimizer
        self.opt_state = optimizer.init(lora)
        self.devices = devices
        self.server = server
        self.channels = channels
        self.datasets = datasets
        self.sim = sim
        self.policy_name = policy
        self.static_cut = static_cut
        self.executor = SplitExecutor(cfg, compress=compress)
        self.rng = np.random.default_rng(7)

    def _decide(self, ctx: RoundContext) -> card_lib.Decision:
        if self.policy_name == "static":
            assert self.static_cut is not None
            return card_lib.static_cut(ctx, self.static_cut)
        if self.policy_name == "random":
            return card_lib.random_cut(ctx, self.rng)
        return POLICIES[self.policy_name](ctx)

    def run_round(self, n: int, device_idx: int) -> RoundLog:
        dev = self.devices[device_idx]
        chan_state = self.channels[device_idx].draw()
        workload = Workload(self.cost_cfg, self.sim.mini_batch,
                            self.sim.seq_len)
        ctx = RoundContext(workload=workload, device=dev, server=self.server,
                           channel=chan_state, sim=self.sim)
        # Stage 1: splitting decision (cut index mapped onto the trained
        # stack if the cost model uses the full-size config)
        decision = self._decide(ctx)
        cut = decision.cut
        if self.cost_cfg.n_layers != self.cfg.n_layers:
            cut = round(cut * self.cfg.n_layers / self.cost_cfg.n_layers)

        # Stages 2-5: T local epochs of real split training. Only the last
        # epoch's loss is logged, so the device sync happens once after the
        # loop instead of serializing every epoch.
        loss = None
        for _ in range(self.sim.local_epochs):
            batch = self.datasets[device_idx].minibatch(
                self.sim.mini_batch, self.sim.seq_len)
            loss, grads = self.executor.step(
                self.frozen, self.lora, batch, cut)
            updates, self.opt_state = self.optimizer.update(
                grads, self.opt_state, self.lora)
            self.lora = apply_updates(self.lora, updates)
        loss_val = float(loss) if loss is not None else float("nan")

        return RoundLog(round_idx=n, device=dev.name, cut=cut,
                        frequency=decision.frequency, delay=decision.delay,
                        server_energy=decision.energy, loss=loss_val,
                        cost=decision.cost)

    def run(self, n_rounds: int) -> TrainResult:
        logs: List[RoundLog] = []
        for n in range(n_rounds):
            for m in range(len(self.devices)):
                logs.append(self.run_round(n, m))
        return TrainResult(lora=self.lora, logs=logs)
