"""The paper's decision stack: energy-efficient split learning for LLM
fine-tuning — cost model (Sec. III), CARD (Sec. IV), the SL protocol
(Sec. II-B stages 1-5) and its real JAX split execution (jax.vjp boundary).
"""
from repro.core import (card, channel, cost_model, hardware, protocol,
                        scheduler, splitting)  # noqa: F401
