"""Decision-level fleet simulator (no JAX execution) — used for the paper's
figures, which need many rounds x devices x policies cheaply.

``simulate_fleet`` reproduces the experiment grid of Sec. V: per round, per
device, draw a channel state, run the policy, log (cut, f, delay, energy).
The numbers feed Fig. 3 / Fig. 4 style benchmarks and the EXPERIMENTS.md
validation against the paper's 70.8% / 53.1% claims.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import card as card_lib
from repro.core.channel import WirelessChannel
from repro.core.cost_model import RoundContext, Workload
from repro.core.hardware import (DEFAULT_SIM, EDGE_FLEET, SERVER_RTX4060TI,
                                 DeviceProfile, SimParams)


@dataclasses.dataclass
class FleetLog:
    policy: str
    channel_state: str
    rounds: int
    device_names: List[str]
    cuts: np.ndarray        # (rounds, devices)
    freqs: np.ndarray       # (rounds, devices) Hz
    delays: np.ndarray      # (rounds, devices) s
    energies: np.ndarray    # (rounds, devices) J

    def mean_delay(self) -> float:
        return float(self.delays.mean())

    def mean_energy(self) -> float:
        return float(self.energies.mean())


def simulate_fleet(cfg: ModelConfig, *, policy: str = "card",
                   channel_state: str = "normal", rounds: int = 50,
                   devices: Sequence[DeviceProfile] = EDGE_FLEET,
                   server: DeviceProfile = SERVER_RTX4060TI,
                   sim: SimParams = DEFAULT_SIM, seed: int = 0,
                   static_cut: Optional[int] = None,
                   respect_memory: bool = True) -> FleetLog:
    rng = np.random.default_rng(seed)
    channels = [WirelessChannel(channel_state, seed=seed + 31 * m,
                                bandwidth_hz=sim.bandwidth_hz,
                                tx_power_dbm_up=sim.tx_power_dbm_up,
                                tx_power_dbm_down=sim.tx_power_dbm_down,
                                noise_dbm_per_hz=sim.noise_dbm_per_hz)
                for m in range(len(devices))]
    workload = Workload(cfg, sim.mini_batch, sim.seq_len)
    nd = len(devices)
    cuts = np.zeros((rounds, nd), np.int32)
    freqs = np.zeros((rounds, nd))
    delays = np.zeros((rounds, nd))
    energies = np.zeros((rounds, nd))
    for n in range(rounds):
        for m, dev in enumerate(devices):
            ctx = RoundContext(workload=workload, device=dev, server=server,
                               channel=channels[m].draw(), sim=sim)
            if policy == "card":
                d = card_lib.card(ctx, respect_memory=respect_memory)
            elif policy == "server_only":
                d = card_lib.server_only(ctx)
            elif policy == "device_only":
                d = card_lib.device_only(ctx)
            elif policy == "static":
                assert static_cut is not None
                d = card_lib.static_cut(ctx, static_cut)
            elif policy == "random":
                d = card_lib.random_cut(ctx, rng)
            else:
                raise ValueError(f"unknown policy {policy!r}")
            cuts[n, m] = d.cut
            freqs[n, m] = d.frequency
            delays[n, m] = d.delay
            energies[n, m] = d.energy
    return FleetLog(policy=policy, channel_state=channel_state, rounds=rounds,
                    device_names=[d.name for d in devices], cuts=cuts,
                    freqs=freqs, delays=delays, energies=energies)


def parallel_round_stats(log: FleetLog, server: DeviceProfile = SERVER_RTX4060TI,
                         sim: SimParams = DEFAULT_SIM) -> Dict[str, float]:
    """Beyond-paper extension (the paper's cited future work, cf. Wu et al.
    JSAC'23 parallel SL): all M devices train concurrently and the server
    splits its compute among them.

    The paper's protocol is sequential — round time = sum over devices. In
    the parallel variant each device's server-side share runs at f*/M
    effective throughput (cubic power => same energy per unit work at fixed
    f), so:

      T_seq  = sum_m D_m
      T_par  = max_m D_m(fـeff = f*_m / M-share)

    We approximate the M-way server share by scaling each device's
    server-compute delay by M (worst case, no pipelining credit).
    """
    m = len(log.device_names)
    t_seq = float(log.delays.sum(axis=1).mean())
    # without per-component breakdown we bound: server-side <= whole delay
    # at c=0 -> parallel upper bound scales delays by M then takes max
    t_par_ub = float(np.max(log.delays * m, axis=1).mean())
    # lower bound: perfect overlap of communication/device compute
    t_par_lb = float(np.max(log.delays, axis=1).mean())
    return {"sequential_s": t_seq, "parallel_upper_s": t_par_ub,
            "parallel_lower_s": t_par_lb,
            "speedup_lb": t_seq / t_par_ub if t_par_ub else float("nan"),
            "speedup_ub": t_seq / t_par_lb if t_par_lb else float("nan")}


def compare_policies(cfg: ModelConfig, *, rounds: int = 50,
                     channel_states: Sequence[str] = ("good", "normal", "poor"),
                     seed: int = 0, sim: SimParams = DEFAULT_SIM
                     ) -> Dict[str, Dict[str, FleetLog]]:
    """The Fig. 4 grid: policy x channel state."""
    out: Dict[str, Dict[str, FleetLog]] = {}
    for policy in ("card", "server_only", "device_only"):
        out[policy] = {}
        for state in channel_states:
            out[policy][state] = simulate_fleet(
                cfg, policy=policy, channel_state=state, rounds=rounds,
                seed=seed, sim=sim)
    return out
