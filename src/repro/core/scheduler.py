"""Decision-level fleet simulator (no JAX model execution) — used for the
paper's figures, which need many rounds x devices x policies cheaply.

``simulate_fleet`` reproduces the experiment grid of Sec. V: per round, per
device, draw a channel state, run the policy, log (cut, f, delay, energy).
The numbers feed Fig. 3 / Fig. 4 style benchmarks and the EXPERIMENTS.md
validation against the paper's 70.8% / 53.1% claims.

Two engines share one cost model:

  engine="vectorized" (default) — all channel states drawn up front
      ((rounds, devices) batch), then the whole (rounds, devices, cuts)
      decision grid runs under jax.jit via ``card.batched_card``. This is
      the path that scales to thousand-device heterogeneous fleets.
  engine="scalar" — the original per-(round, device) Python loop, kept as
      the reference oracle; both engines consume identical channel
      realizations, so their logs agree decision-for-decision.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import card as card_lib
from repro.core.channel import (SEED_STRIDE, WirelessChannel,
                                draw_channel_matrix)
from repro.core.cost_model import BatchedRoundContext, RoundContext, Workload
from repro.core.faults import DeadlinePolicy, FaultModel, FaultRealization
from repro.core.hardware import (DEFAULT_SIM, EDGE_FLEET, SERVER_RTX4060TI,
                                 DeviceProfile, SimParams)


def _masked_mean(a: np.ndarray) -> float:
    """Mean over non-NaN entries; NaN for an all-NaN (or empty) array.

    Dropped devices are logged as NaN — a plain ``.mean()`` would silently
    poison every Fig. 3/4 aggregate the moment one device misses a round.
    """
    a = np.asarray(a, np.float64)
    mask = ~np.isnan(a)
    if not mask.any():
        return float("nan")
    return float(a[mask].mean())


def _masked_rowmax(a: np.ndarray) -> np.ndarray:
    """Per-round max over non-NaN entries; NaN rows where nothing survived
    (avoids numpy's all-NaN-slice RuntimeWarning from ``nanmax``)."""
    filled = np.where(np.isnan(a), -np.inf, a)
    out = filled.max(axis=1)
    return np.where(np.isinf(out), np.nan, out)


@dataclasses.dataclass
class FleetLog:
    """Per-(round, device) output of ``simulate_fleet``: all arrays are
    (rounds, devices) — ``freqs`` in Hz, ``delays`` (and the d_* component
    breakdown) in seconds, ``energies`` in joules; under churn,
    non-survivor lanes are NaN with ``participation`` marking commits."""
    policy: str
    channel_state: str
    rounds: int
    device_names: List[str]
    cuts: np.ndarray        # (rounds, devices)
    freqs: np.ndarray       # (rounds, devices) Hz
    delays: np.ndarray      # (rounds, devices) s
    energies: np.ndarray    # (rounds, devices) J
    # per-component delay breakdown (device / uplink / server / downlink);
    # filled by both engines, enables exact parallel-SL round times
    d_device: Optional[np.ndarray] = None
    d_uplink: Optional[np.ndarray] = None
    d_server: Optional[np.ndarray] = None
    d_downlink: Optional[np.ndarray] = None
    # churn extension (apply_faults): True where the device's round result
    # was committed; non-survivor delay/energy entries are NaN
    participation: Optional[np.ndarray] = None   # bool (rounds, devices)
    round_close_s: Optional[np.ndarray] = None   # (rounds,) server close time
    fault_realization: Optional[FaultRealization] = None

    def mean_delay(self) -> float:
        return _masked_mean(self.delays)

    def mean_energy(self) -> float:
        return _masked_mean(self.energies)

    def survivor_fraction(self) -> float:
        """Fraction of (round, device) slots whose result was committed."""
        if self.participation is None:
            return 1.0
        return float(self.participation.mean())


def _simulate_fleet_scalar(cfg: ModelConfig, *, policy: str,
                           channel_state: str, rounds: int,
                           devices: Sequence[DeviceProfile],
                           server: DeviceProfile, sim: SimParams, seed: int,
                           static_cut: Optional[int], respect_memory: bool,
                           cost_source: str, latency_table,
                           deadline_spec) -> FleetLog:
    """Reference oracle: the original triple loop, one decision at a time."""
    rng = np.random.default_rng(seed)
    channels = [WirelessChannel(channel_state, seed=seed + SEED_STRIDE * m,
                                bandwidth_hz=sim.bandwidth_hz,
                                tx_power_dbm_up=sim.tx_power_dbm_up,
                                tx_power_dbm_down=sim.tx_power_dbm_down,
                                noise_dbm_per_hz=sim.noise_dbm_per_hz)
                for m in range(len(devices))]
    workload = Workload(cfg, sim.mini_batch, sim.seq_len)
    nd = len(devices)
    cuts = np.zeros((rounds, nd), np.int32)
    freqs = np.zeros((rounds, nd))
    delays = np.zeros((rounds, nd))
    energies = np.zeros((rounds, nd))
    parts = {k: np.zeros((rounds, nd))
             for k in ("d_device", "d_uplink", "d_server", "d_downlink")}
    for n in range(rounds):
        for m, dev in enumerate(devices):
            ctx = RoundContext(workload=workload, device=dev, server=server,
                               channel=channels[m].draw(), sim=sim,
                               cost_source=cost_source,
                               latency_table=latency_table)
            if policy == "card":
                d = card_lib.card(ctx, respect_memory=respect_memory,
                                  deadline=deadline_spec)
            elif policy == "server_only":
                d = card_lib.server_only(ctx)
            elif policy == "device_only":
                d = card_lib.device_only(ctx)
            elif policy == "static":
                assert static_cut is not None
                d = card_lib.static_cut(ctx, static_cut)
            elif policy == "random":
                d = card_lib.random_cut(ctx, rng)
            else:
                raise ValueError(f"unknown policy {policy!r}")
            cuts[n, m] = d.cut
            freqs[n, m] = d.frequency
            delays[n, m] = d.delay
            energies[n, m] = d.energy
            br = ctx.delay_components(d.cut, d.frequency)
            parts["d_device"][n, m] = br.device_comp
            parts["d_uplink"][n, m] = br.uplink
            parts["d_server"][n, m] = br.server_comp
            parts["d_downlink"][n, m] = br.downlink
    return FleetLog(policy=policy, channel_state=channel_state, rounds=rounds,
                    device_names=[d.name for d in devices], cuts=cuts,
                    freqs=freqs, delays=delays, energies=energies, **parts)


def _simulate_fleet_vectorized(cfg: ModelConfig, *, policy: str,
                               channel_state: str, rounds: int,
                               devices: Sequence[DeviceProfile],
                               server: DeviceProfile, sim: SimParams,
                               seed: int, static_cut: Optional[int],
                               respect_memory: bool, cost_source: str,
                               latency_table, deadline_spec) -> FleetLog:
    """All channel states up front, one jitted grid evaluation per policy."""
    nd = len(devices)
    batch = draw_channel_matrix(channel_state, rounds, nd, seed=seed,
                                bandwidth_hz=sim.bandwidth_hz,
                                tx_power_dbm_up=sim.tx_power_dbm_up,
                                tx_power_dbm_down=sim.tx_power_dbm_down,
                                noise_dbm_per_hz=sim.noise_dbm_per_hz)
    workload = Workload(cfg, sim.mini_batch, sim.seq_len)
    bctx = BatchedRoundContext.build(workload, devices, server, batch, sim,
                                     cost_source=cost_source,
                                     latency_table=latency_table)
    if policy == "card":
        dec = card_lib.batched_card(bctx, respect_memory=respect_memory,
                                    deadline=deadline_spec)
    elif policy == "server_only":
        dec = card_lib.batched_server_only(bctx)
    elif policy == "device_only":
        dec = card_lib.batched_device_only(bctx)
    elif policy == "static":
        assert static_cut is not None
        dec = card_lib.batched_static_cut(bctx, static_cut)
    elif policy == "random":
        # same stream the scalar loop consumes for its per-decision draws
        rng = np.random.default_rng(seed)
        draws = rng.integers(0, cfg.n_layers + 1, size=(rounds, nd))
        dec = card_lib.batched_static_cut(bctx, draws)
    else:
        raise ValueError(f"unknown policy {policy!r}")
    # one device_get for the whole decision pytree instead of eight
    # separate device->host transfers
    host = jax.device_get(dec)
    return FleetLog(policy=policy, channel_state=channel_state, rounds=rounds,
                    device_names=[d.name for d in devices],
                    cuts=np.asarray(host.cuts, np.int32),
                    freqs=np.asarray(host.freqs, np.float64),
                    delays=np.asarray(host.delays, np.float64),
                    energies=np.asarray(host.energies, np.float64),
                    d_device=np.asarray(host.d_device, np.float64),
                    d_uplink=np.asarray(host.d_uplink, np.float64),
                    d_server=np.asarray(host.d_server, np.float64),
                    d_downlink=np.asarray(host.d_downlink, np.float64))


def _shard_pad(a: np.ndarray, pad: int, value) -> np.ndarray:
    """Pad the trailing (devices) axis with ``value`` lanes.

    Pad lanes are real finite decision problems (rate 1 bit/s, 1 FLOP/s
    device) whose results are sliced off after the sharded call — padding
    with NaN/0 would poison argmin/div inside the grid."""
    if pad == 0:
        return np.asarray(a)
    width = [(0, 0)] * (np.ndim(a) - 1) + [(0, pad)]
    return np.pad(np.asarray(a), width, constant_values=value)


def _simulate_fleet_sharded(cfg: ModelConfig, *, mesh, policy: str,
                            channel_state: str, rounds: int,
                            devices: Sequence[DeviceProfile],
                            server: DeviceProfile, sim: SimParams,
                            seed: int, static_cut: Optional[int],
                            respect_memory: bool, cost_source: str,
                            latency_table, deadline_spec) -> FleetLog:
    """The vectorized engine with the *devices* axis sharded over a 1-D
    ``("data",)`` mesh — one ``jit(shard_map(...))`` call for the whole
    fleet, the 10^6-device path.

    Bit-identical to ``engine="vectorized"`` on one host: every per-lane
    quantity in the (rounds, devices, cuts) grid — corners, Eq. 16 f*, the
    argmin over cuts — is computed from that device's own lane (no
    cross-device reduction anywhere in ``batched_card``), so sharding the
    axis changes data placement, never values. Channel draws stay on the
    host (same ``draw_channel_matrix`` stream), devices are padded to a
    shard multiple with dummy lanes and trimmed off the result.
    """
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import fleet_shard_map

    nd = len(devices)
    batch = draw_channel_matrix(channel_state, rounds, nd, seed=seed,
                                bandwidth_hz=sim.bandwidth_hz,
                                tx_power_dbm_up=sim.tx_power_dbm_up,
                                tx_power_dbm_down=sim.tx_power_dbm_down,
                                noise_dbm_per_hz=sim.noise_dbm_per_hz)
    workload = Workload(cfg, sim.mini_batch, sim.seq_len)
    bctx = BatchedRoundContext.build(workload, devices, server, batch, sim,
                                     cost_source=cost_source,
                                     latency_table=latency_table)
    n_shards = int(np.prod(mesh.devices.shape))
    pad = (-nd) % n_shards
    bctx = dataclasses.replace(
        bctx,
        peak_flops=_shard_pad(bctx.peak_flops, pad, 1.0),
        max_cut=_shard_pad(bctx.max_cut, pad, 0),
        rate_up=_shard_pad(bctx.rate_up, pad, 1.0),
        rate_down=_shard_pad(bctx.rate_down, pad, 1.0))
    # same pytree, PartitionSpec leaves: tables/weights replicated, every
    # device-axis field sharded on "data"
    specs = dataclasses.replace(
        bctx, dev_flops=P(), srv_flops=P(), up_bits=P(), down_bits=P(),
        adapter_bits=P(), peak_flops=P("data"), max_cut=P("data"),
        rate_up=P(None, "data"), rate_down=P(None, "data"), w=P(), xi=P())
    if policy == "random":
        rng = np.random.default_rng(seed)
        draws = rng.integers(0, cfg.n_layers + 1, size=(rounds, nd))
    else:
        draws = np.zeros((rounds, nd), np.int64)
    draws = _shard_pad(draws, pad, 0)

    def _decide(ctx, cut_draws):
        if policy == "card":
            return card_lib.batched_card(ctx, respect_memory=respect_memory,
                                         deadline=deadline_spec)
        if policy == "server_only":
            return card_lib.batched_server_only(ctx)
        if policy == "device_only":
            return card_lib.batched_device_only(ctx)
        if policy in ("static", "random"):
            cut = static_cut if policy == "static" else cut_draws
            return card_lib.batched_static_cut(ctx, cut)
        raise ValueError(f"unknown policy {policy!r}")

    # eager shard_map (no outer jit): the policy fns are already jitted, so
    # each shard runs the *same compiled executable* as the unsharded
    # engine — wrapping the shard_map in another jit would inline that jit
    # and let XLA re-fuse the grid differently (one-ulp drift in the logs),
    # breaking the bit-identity contract this engine is tested against
    sharded = fleet_shard_map(_decide, mesh,
                              in_specs=(specs, P(None, "data")),
                              out_specs=P(None, "data"))
    host = jax.device_get(sharded(bctx, draws))
    trim = {f: np.asarray(getattr(host, f))[:, :nd]
            for f in ("cuts", "freqs", "delays", "energies",
                      "d_device", "d_uplink", "d_server", "d_downlink")}
    return FleetLog(policy=policy, channel_state=channel_state, rounds=rounds,
                    device_names=[d.name for d in devices],
                    cuts=trim["cuts"].astype(np.int32),
                    freqs=trim["freqs"].astype(np.float64),
                    delays=trim["delays"].astype(np.float64),
                    energies=trim["energies"].astype(np.float64),
                    d_device=trim["d_device"].astype(np.float64),
                    d_uplink=trim["d_uplink"].astype(np.float64),
                    d_server=trim["d_server"].astype(np.float64),
                    d_downlink=trim["d_downlink"].astype(np.float64))


def apply_faults(log: FleetLog, realization: FaultRealization,
                 deadline: Optional[DeadlinePolicy] = None) -> FleetLog:
    """Overlay a fault realization on a decision log (both engines share
    this, so fault handling can never make them drift).

    Decisions stay as made — the server cannot know in advance who will
    straggle — but what the fleet *experiences* changes: straggler factors
    stretch the device-compute and radio delay components, outages add a
    retransmission stall, and dropped-out / departed devices never report.
    With a :class:`DeadlinePolicy`, the server closes each round at the
    ``quantile`` of that round's *predicted* (nominal decision) delays over
    its members; devices whose realized delay exceeds it are late and
    dropped from the round (partial aggregation). Non-survivor delay/energy
    entries become NaN — all ``FleetLog`` reductions are NaN-safe.
    """
    if realization.active.shape != log.delays.shape:
        raise ValueError(f"realization shape {realization.active.shape} != "
                         f"log shape {log.delays.shape}")
    started = realization.participating           # active & not dropped out
    # realized per-component delays (stall folded into the uplink term so
    # components still sum to the realized total)
    dev = log.d_device * realization.compute_slowdown
    up = (log.d_uplink * realization.link_slowdown
          + np.where(realization.outage, realization.outage_stall_s, 0.0))
    down = log.d_downlink * realization.link_slowdown
    # untouched entries keep the logged total verbatim (re-summing the
    # components reorders float rounding) — the zero-fault degenerate case
    # must be bit-identical to the fault-free log
    untouched = ((realization.compute_slowdown == 1.0)
                 & (realization.link_slowdown == 1.0) & ~realization.outage)
    realized = np.where(untouched, log.delays,
                        dev + up + log.d_server + down)

    n_rounds = log.delays.shape[0]
    deadline_s = np.full(n_rounds, np.inf)
    if deadline is not None:
        membered = realization.active.any(axis=1)
        pred = np.where(realization.active, log.delays, np.nan)
        if membered.any():
            deadline_s[membered] = np.nanquantile(
                pred[membered], deadline.quantile, axis=1)
        late = started & (realized > deadline_s[:, None])
    else:
        late = np.zeros_like(started)
    survivors = started & ~late

    # server close time: the deadline if any member failed to report in
    # time, else the last report; NaN when the round had no members at all
    last_report = _masked_rowmax(np.where(survivors, realized, np.nan))
    all_reported = (realization.active == survivors).all(axis=1)
    close_s = np.where(all_reported, last_report,
                       np.where(np.isinf(deadline_s), last_report,
                                deadline_s))

    def _mask(a):
        return np.where(survivors, a, np.nan)

    return dataclasses.replace(
        log, delays=_mask(realized), energies=_mask(log.energies),
        d_device=_mask(dev), d_uplink=_mask(up),
        d_server=_mask(log.d_server), d_downlink=_mask(down),
        participation=survivors, round_close_s=close_s,
        fault_realization=realization)


def simulate_fleet(cfg: ModelConfig, *, policy: str = "card",
                   channel_state: str = "normal", rounds: int = 50,
                   devices: Sequence[DeviceProfile] = EDGE_FLEET,
                   server: DeviceProfile = SERVER_RTX4060TI,
                   sim: SimParams = DEFAULT_SIM, seed: int = 0,
                   static_cut: Optional[int] = None,
                   respect_memory: bool = True,
                   engine: str = "vectorized",
                   cost_source: str = "analytic",
                   latency_table=None,
                   fault_model: Optional[FaultModel] = None,
                   deadline: Optional[DeadlinePolicy] = None,
                   mesh=None) -> FleetLog:
    """Run ``rounds`` of per-device CARD (or baseline) decisions.

    ``cost_source="measured"`` routes per-cut compute delays through a
    kernel-calibrated ``measured_cost.LatencyTable`` instead of the paper's
    analytic FLOP counts; both engines honor it identically.

    ``fault_model`` overlays dropout/straggler/outage/membership churn on
    the log (see :func:`apply_faults`); ``fault_model=None`` is bit-exactly
    today's fault-free simulation. ``deadline`` sets the round-closing
    policy and, when ``objective_deadline_s`` is set, routes a
    straggler-aware :class:`card.DeadlineSpec` into the CARD objective —
    both engines consume the identical spec.

    ``mesh`` (a 1-D ``("data",)`` mesh from ``launch.mesh.make_fleet_mesh``)
    shards the devices axis of the vectorized engine across host devices in
    one ``jit(shard_map(...))`` call — bit-identical to the unsharded
    vectorized engine, scales the sweep to 10^6 devices.
    """
    deadline_spec = None
    if deadline is not None and deadline.objective_deadline_s is not None:
        deadline_spec = card_lib.DeadlineSpec(
            deadline_s=float(deadline.objective_deadline_s),
            p_dropout=fault_model.dropout_prob if fault_model else 0.0,
            p_straggler=fault_model.straggler_prob if fault_model else 0.0,
            slowdown=fault_model.mean_slowdown if fault_model else 1.0,
            penalty=float(deadline.objective_penalty))
    kwargs = dict(policy=policy, channel_state=channel_state, rounds=rounds,
                  devices=devices, server=server, sim=sim, seed=seed,
                  static_cut=static_cut, respect_memory=respect_memory,
                  cost_source=cost_source, latency_table=latency_table,
                  deadline_spec=deadline_spec)
    if mesh is not None:
        if engine != "vectorized":
            raise ValueError(f"mesh= requires engine='vectorized', "
                             f"got {engine!r}")
        log = _simulate_fleet_sharded(cfg, mesh=mesh, **kwargs)
    elif engine == "vectorized":
        log = _simulate_fleet_vectorized(cfg, **kwargs)
    elif engine == "scalar":
        log = _simulate_fleet_scalar(cfg, **kwargs)
    else:
        raise ValueError(f"unknown engine {engine!r}")
    if fault_model is not None:
        realization = fault_model.realize(rounds, len(devices), seed=seed)
        log = apply_faults(log, realization, deadline)
    return log


def parallel_round_stats(log: FleetLog, server: DeviceProfile = SERVER_RTX4060TI,
                         sim: SimParams = DEFAULT_SIM) -> Dict[str, float]:
    """Beyond-paper extension (the paper's cited future work, cf. Wu et al.
    JSAC'23 parallel SL): all M devices train concurrently and the server
    splits its compute among them.

    The paper's protocol is sequential — round time = sum over devices. In
    the parallel variant each device's server-side share runs at 1/M of the
    server throughput (cubic power => same energy per unit work at fixed f),
    while device compute and the per-device radio links genuinely overlap:

      T_seq  = sum_m D_m
      T_par  = max_m (D_m^dev + D_m^up + M * D_m^srv + D_m^down)

    With the per-component breakdown in ``FleetLog`` this is exact (no
    pipelining credit); the legacy upper/lower bounds — which bracketed it
    when only the scalar total was logged — are kept for comparison.
    """
    # All reductions are masked: dropped (NaN) entries contribute nothing
    # to sums, maxes, or means — a churned fleet reports exact round times
    # over its survivors instead of NaN-poisoned aggregates.
    valid = ~np.isnan(log.delays)                         # (R, D)
    survivors = valid.sum(axis=1)                         # (R,)
    t_seq = _masked_mean(np.where(
        survivors > 0, np.where(valid, log.delays, 0.0).sum(axis=1), np.nan))
    # legacy bounds: server-side <= whole delay -> scale everything by M (ub);
    # perfect overlap of communication/device compute (lb)
    t_par_ub = _masked_mean(_masked_rowmax(log.delays * survivors[:, None]))
    t_par_lb = _masked_mean(_masked_rowmax(log.delays))
    out = {"sequential_s": t_seq, "parallel_upper_s": t_par_ub,
           "parallel_lower_s": t_par_lb,
           "speedup_lb": t_seq / t_par_ub if t_par_ub else float("nan"),
           "speedup_ub": t_seq / t_par_lb if t_par_lb else float("nan")}
    if log.d_server is not None:
        # the server splits its compute among that round's survivors only
        per_dev = (log.d_device + log.d_uplink
                   + survivors[:, None] * log.d_server + log.d_downlink)
        t_par = _masked_mean(_masked_rowmax(per_dev))
        out["parallel_exact_s"] = t_par
        out["speedup_exact"] = t_seq / t_par if t_par else float("nan")
    return out


def compare_policies(cfg: ModelConfig, *, rounds: int = 50,
                     channel_states: Sequence[str] = ("good", "normal", "poor"),
                     seed: int = 0, sim: SimParams = DEFAULT_SIM,
                     devices: Sequence[DeviceProfile] = EDGE_FLEET,
                     server: DeviceProfile = SERVER_RTX4060TI,
                     engine: str = "vectorized"
                     ) -> Dict[str, Dict[str, FleetLog]]:
    """The Fig. 4 grid: policy x channel state."""
    out: Dict[str, Dict[str, FleetLog]] = {}
    for policy in ("card", "server_only", "device_only"):
        out[policy] = {}
        for state in channel_states:
            out[policy][state] = simulate_fleet(
                cfg, policy=policy, channel_state=state, rounds=rounds,
                seed=seed, sim=sim, devices=devices, server=server,
                engine=engine)
    return out


# ---------------------------------------------------------------------------
# Hierarchical (multi-server) fleet sweep
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HierarchicalLog:
    """``simulate_fleet`` summary for a server *tier* (hierarchical SL).

    ``decision`` is the full :class:`card.HierarchicalDecision` (assignment
    (D,), per-device (R, D) grids in s/J/Hz, per-server (S, R)
    ``aggregation_s``); the round-time fields fold the backhaul stage in:
    a round ends when the slowest server has finished its slowest device
    *and* pushed its aggregated adapters upstream.
    """
    channel_state: str
    rounds: int
    n_servers: int
    decision: "card_lib.HierarchicalDecision"
    round_s: np.ndarray          # (rounds,) max over servers incl. backhaul
    server_round_s: np.ndarray   # (S, rounds) per-server close time

    def mean_round_s(self) -> float:
        return float(self.round_s.mean())

    def mean_delay(self) -> float:
        return _masked_mean(self.decision.delays)

    def mean_energy(self) -> float:
        return _masked_mean(self.decision.energies)


def simulate_hierarchical_fleet(cfg: ModelConfig, *,
                                tier, rounds: int = 50,
                                devices: Sequence[DeviceProfile] = EDGE_FLEET,
                                channel_state: str = "normal",
                                sim: SimParams = DEFAULT_SIM, seed: int = 0,
                                assign: str = "greedy",
                                respect_memory: bool = True
                                ) -> HierarchicalLog:
    """One hierarchical CARD sweep: draw the (rounds, devices) channel block
    (same stream as the flat engines), run :func:`card.hierarchical_card`
    against the :class:`hardware.ServerTier`, and fold per-server parallel
    round times with the backhaul aggregation stage."""
    from repro.core.cost_model import TieredRoundContext

    batch = draw_channel_matrix(channel_state, rounds, len(devices),
                                seed=seed, bandwidth_hz=sim.bandwidth_hz,
                                tx_power_dbm_up=sim.tx_power_dbm_up,
                                tx_power_dbm_down=sim.tx_power_dbm_down,
                                noise_dbm_per_hz=sim.noise_dbm_per_hz)
    workload = Workload(cfg, sim.mini_batch, sim.seq_len)
    tctx = TieredRoundContext.build(workload, devices, tier, batch, sim)
    dec = card_lib.hierarchical_card(tctx, respect_memory=respect_memory,
                                     assign=assign)
    # per-server close: slowest assigned device — with the server's compute
    # split among its load (a device's decision prices one d_server share;
    # hosting L devices stretches that share L-fold, exactly the contention
    # rule parallel_round_stats applies to the flat engine) — then the
    # backhaul push
    assign_mask = dec.assignment[None, :] == np.arange(tier.n_servers)[:, None]
    load = np.maximum(dec.server_load, 1)[dec.assignment]       # (D,)
    contended = dec.delays + (load - 1)[None, :] * dec.d_server  # (R, D)
    per_srv = np.where(assign_mask[:, None, :], contended[None], np.nan)
    slowest = np.where(assign_mask.any(axis=1)[:, None],
                       _masked_rowmax(per_srv.reshape(-1, len(devices)))
                       .reshape(tier.n_servers, rounds), 0.0)
    server_round_s = slowest + dec.aggregation_s
    return HierarchicalLog(channel_state=channel_state, rounds=rounds,
                           n_servers=tier.n_servers, decision=dec,
                           round_s=server_round_s.max(axis=0),
                           server_round_s=server_round_s)
