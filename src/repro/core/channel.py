"""Wireless channel model: pathloss -> SNR -> CQI -> MCS spectral efficiency.

The paper (Sec. III-A-2) converts SNR to rate via the 3GPP TS 38.214 CQI->MCS
mapping: ``R = B * y(SNR)`` where ``y`` is the spectral efficiency of the
highest CQI whose SNR threshold is met. Channel states Good/Normal/Poor are
pathloss exponents 2/4/6 (Sec. V-B).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

# 3GPP TS 38.214 Table 5.2.2.1-2 (4-bit CQI, 64QAM): spectral efficiency and
# the commonly used SNR switching thresholds (dB) from link-level curves.
CQI_EFFICIENCY = (
    0.1523, 0.2344, 0.3770, 0.6016, 0.8770, 1.1758, 1.4766, 1.9141,
    2.4063, 2.7305, 3.3223, 3.9023, 4.5234, 5.1152, 5.5547,
)
CQI_SNR_THRESH_DB = (
    -6.7, -4.7, -2.3, 0.2, 2.4, 4.3, 5.9, 8.1,
    10.3, 11.7, 14.1, 16.3, 18.7, 21.0, 22.7,
)

PATHLOSS_EXPONENT = {"good": 2.0, "normal": 4.0, "poor": 6.0}


def snr_to_efficiency(snr_db: float) -> float:
    """y(SNR): highest CQI whose threshold is met (0 below CQI-1)."""
    eff = 0.0
    for thresh, e in zip(CQI_SNR_THRESH_DB, CQI_EFFICIENCY):
        if snr_db >= thresh:
            eff = e
    return eff


def pathloss_db(distance_m: float, exponent: float, *,
                ref_loss_db: float = 30.0, ref_dist_m: float = 1.0) -> float:
    return ref_loss_db + 10.0 * exponent * math.log10(
        max(distance_m, ref_dist_m) / ref_dist_m)


@dataclass
class ChannelState:
    """Per-(device, round) link realization."""
    snr_up_db: float
    snr_down_db: float
    bandwidth_hz: float

    @property
    def rate_up(self) -> float:      # R^D in the paper, bits/s
        # floor at CQI-1 (lowest MCS with HARQ retransmission) to avoid outage
        return self.bandwidth_hz * max(snr_to_efficiency(self.snr_up_db),
                                       CQI_EFFICIENCY[0])

    @property
    def rate_down(self) -> float:    # R^S
        return self.bandwidth_hz * max(snr_to_efficiency(self.snr_down_db),
                                       CQI_EFFICIENCY[0])


class WirelessChannel:
    """Draws per-round channel states with Rayleigh block fading."""

    def __init__(self, state: str = "normal", *, distance_m: float = 35.0,
                 bandwidth_hz: float = 20e6, tx_power_dbm_up: float = 23.0,
                 tx_power_dbm_down: float = 30.0,
                 noise_dbm_per_hz: float = -174.0, fading: bool = True,
                 seed: int = 0):
        if state not in PATHLOSS_EXPONENT:
            raise ValueError(f"channel state must be one of {list(PATHLOSS_EXPONENT)}")
        self.state = state
        self.exponent = PATHLOSS_EXPONENT[state]
        self.distance_m = distance_m
        self.bandwidth_hz = bandwidth_hz
        self.tx_up = tx_power_dbm_up
        self.tx_down = tx_power_dbm_down
        self.noise_dbm = noise_dbm_per_hz + 10 * math.log10(bandwidth_hz)
        self.fading = fading
        self.rng = np.random.default_rng(seed)

    def mean_snr_db(self, uplink: bool) -> float:
        tx = self.tx_up if uplink else self.tx_down
        return tx - pathloss_db(self.distance_m, self.exponent) - self.noise_dbm

    def draw(self) -> ChannelState:
        """One block-fading realization (fixed within a training round)."""
        if self.fading:
            # Rayleigh: |h|^2 ~ Exp(1) -> dB offset
            g_up = 10 * math.log10(max(self.rng.exponential(1.0), 1e-6))
            g_dn = 10 * math.log10(max(self.rng.exponential(1.0), 1e-6))
        else:
            g_up = g_dn = 0.0
        return ChannelState(
            snr_up_db=self.mean_snr_db(True) + g_up,
            snr_down_db=self.mean_snr_db(False) + g_dn,
            bandwidth_hz=self.bandwidth_hz)
