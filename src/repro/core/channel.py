"""Wireless channel model: pathloss -> SNR -> CQI -> MCS spectral efficiency.

The paper (Sec. III-A-2) converts SNR to rate via the 3GPP TS 38.214 CQI->MCS
mapping: ``R = B * y(SNR)`` where ``y`` is the spectral efficiency of the
highest CQI whose SNR threshold is met. Channel states Good/Normal/Poor are
pathloss exponents 2/4/6 (Sec. V-B).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Tuple

import numpy as np

# 3GPP TS 38.214 Table 5.2.2.1-2 (4-bit CQI, 64QAM): spectral efficiency and
# the commonly used SNR switching thresholds (dB) from link-level curves.
CQI_EFFICIENCY = (
    0.1523, 0.2344, 0.3770, 0.6016, 0.8770, 1.1758, 1.4766, 1.9141,
    2.4063, 2.7305, 3.3223, 3.9023, 4.5234, 5.1152, 5.5547,
)
CQI_SNR_THRESH_DB = (
    -6.7, -4.7, -2.3, 0.2, 2.4, 4.3, 5.9, 8.1,
    10.3, 11.7, 14.1, 16.3, 18.7, 21.0, 22.7,
)

PATHLOSS_EXPONENT = {"good": 2.0, "normal": 4.0, "poor": 6.0}

# Shared by the scalar and vectorized fleet engines: per-device PRNG streams
# are seeded ``seed + SEED_STRIDE * device_index`` and devices sit at the
# paper's default AP distance. One definition — the engines must not drift.
SEED_STRIDE = 31
DEFAULT_DISTANCE_M = 35.0


_CQI_TABLE = np.concatenate(([0.0], np.asarray(CQI_EFFICIENCY)))


def snr_to_efficiency(snr_db: float) -> float:
    """y(SNR): highest CQI whose threshold is met (0 below CQI-1)."""
    return float(snr_to_efficiency_array(np.asarray(snr_db)))


def snr_to_efficiency_array(snr_db: np.ndarray) -> np.ndarray:
    """Vectorized y(SNR) over an array of SNRs (dB)."""
    idx = np.searchsorted(np.asarray(CQI_SNR_THRESH_DB), snr_db, side="right")
    return _CQI_TABLE[idx]


def pathloss_db(distance_m: float, exponent: float, *,
                ref_loss_db: float = 30.0, ref_dist_m: float = 1.0) -> float:
    return ref_loss_db + 10.0 * exponent * math.log10(
        max(distance_m, ref_dist_m) / ref_dist_m)


@dataclass
class ChannelState:
    """Per-(device, round) link realization."""
    snr_up_db: float
    snr_down_db: float
    bandwidth_hz: float

    @property
    def rate_up(self) -> float:      # R^D in the paper, bits/s
        # floor at CQI-1 (lowest MCS with HARQ retransmission) to avoid outage
        return self.bandwidth_hz * max(snr_to_efficiency(self.snr_up_db),
                                       CQI_EFFICIENCY[0])

    @property
    def rate_down(self) -> float:    # R^S
        return self.bandwidth_hz * max(snr_to_efficiency(self.snr_down_db),
                                       CQI_EFFICIENCY[0])


@dataclass
class ChannelBatch:
    """(rounds, devices) block of link realizations for a whole simulation.

    Rates apply the same CQI-1 floor as ``ChannelState`` so a batched fleet
    sweep sees bit-identical link budgets to per-round scalar draws.
    """
    snr_up_db: np.ndarray       # (rounds, devices)
    snr_down_db: np.ndarray     # (rounds, devices)
    bandwidth_hz: float

    @property
    def rate_up(self) -> np.ndarray:
        eff = np.maximum(snr_to_efficiency_array(self.snr_up_db),
                         CQI_EFFICIENCY[0])
        return self.bandwidth_hz * eff

    @property
    def rate_down(self) -> np.ndarray:
        eff = np.maximum(snr_to_efficiency_array(self.snr_down_db),
                         CQI_EFFICIENCY[0])
        return self.bandwidth_hz * eff

    @property
    def rounds(self) -> int:
        return self.snr_up_db.shape[0]

    @property
    def n_devices(self) -> int:
        return self.snr_up_db.shape[1]

    def state(self, round_idx: int, device_idx: int) -> ChannelState:
        """The scalar ``ChannelState`` view of one (round, device) cell."""
        return ChannelState(
            snr_up_db=float(self.snr_up_db[round_idx, device_idx]),
            snr_down_db=float(self.snr_down_db[round_idx, device_idx]),
            bandwidth_hz=self.bandwidth_hz)


class WirelessChannel:
    """Draws per-round channel states with Rayleigh block fading."""

    def __init__(self, state: str = "normal", *,
                 distance_m: float = DEFAULT_DISTANCE_M,
                 bandwidth_hz: float = 20e6, tx_power_dbm_up: float = 23.0,
                 tx_power_dbm_down: float = 30.0,
                 noise_dbm_per_hz: float = -174.0, fading: bool = True,
                 seed: int = 0):
        if state not in PATHLOSS_EXPONENT:
            raise ValueError(f"channel state must be one of {list(PATHLOSS_EXPONENT)}")
        self.state = state
        self.exponent = PATHLOSS_EXPONENT[state]
        self.distance_m = distance_m
        self.bandwidth_hz = bandwidth_hz
        self.tx_up = tx_power_dbm_up
        self.tx_down = tx_power_dbm_down
        self.noise_dbm = noise_dbm_per_hz + 10 * math.log10(bandwidth_hz)
        self.fading = fading
        self.rng = np.random.default_rng(seed)

    def mean_snr_db(self, uplink: bool) -> float:
        tx = self.tx_up if uplink else self.tx_down
        return tx - pathloss_db(self.distance_m, self.exponent) - self.noise_dbm

    def draw(self) -> ChannelState:
        """One block-fading realization (fixed within a training round)."""
        if self.fading:
            # Rayleigh: |h|^2 ~ Exp(1) -> dB offset
            g_up = 10 * math.log10(max(self.rng.exponential(1.0), 1e-6))
            g_dn = 10 * math.log10(max(self.rng.exponential(1.0), 1e-6))
        else:
            g_up = g_dn = 0.0
        return ChannelState(
            snr_up_db=self.mean_snr_db(True) + g_up,
            snr_down_db=self.mean_snr_db(False) + g_dn,
            bandwidth_hz=self.bandwidth_hz)

    def draw_rounds(self, rounds: int) -> Tuple[np.ndarray, np.ndarray]:
        """``rounds`` block-fading realizations in one shot.

        Consumes the PRNG stream in exactly the order of ``rounds``
        sequential ``draw()`` calls (up, down, up, down, ...), so the
        batched fleet engine reproduces the scalar simulator bit-for-bit.
        Returns ``(snr_up_db, snr_down_db)``, each shaped ``(rounds,)``.
        """
        if self.fading:
            g = 10 * np.log10(np.maximum(
                self.rng.exponential(1.0, size=(rounds, 2)), 1e-6))
            g_up, g_dn = g[:, 0], g[:, 1]
        else:
            g_up = g_dn = np.zeros(rounds)
        return (self.mean_snr_db(True) + g_up,
                self.mean_snr_db(False) + g_dn)


def draw_channel_matrix(state: str, rounds: int, n_devices: int, *,
                        seed: int = 0, seed_stride: int = SEED_STRIDE,
                        distance_m: float = DEFAULT_DISTANCE_M,
                        bandwidth_hz: float = 20e6,
                        tx_power_dbm_up: float = 23.0,
                        tx_power_dbm_down: float = 30.0,
                        noise_dbm_per_hz: float = -174.0,
                        fading: bool = True) -> ChannelBatch:
    """All (rounds x devices) channel states up front, for the fleet engine.

    Device ``m`` gets its own stream seeded ``seed + seed_stride * m`` — the
    same scheme the scalar simulator uses — so scalar and vectorized sweeps
    observe identical link realizations.
    """
    up = np.empty((rounds, n_devices))
    down = np.empty((rounds, n_devices))
    for m in range(n_devices):
        ch = WirelessChannel(state, seed=seed + seed_stride * m,
                             distance_m=distance_m, bandwidth_hz=bandwidth_hz,
                             tx_power_dbm_up=tx_power_dbm_up,
                             tx_power_dbm_down=tx_power_dbm_down,
                             noise_dbm_per_hz=noise_dbm_per_hz, fading=fading)
        up[:, m], down[:, m] = ch.draw_rounds(rounds)
    return ChannelBatch(snr_up_db=up, snr_down_db=down,
                        bandwidth_hz=bandwidth_hz)
