"""Fault model for churn-tolerant fleet orchestration.

The paper's protocol assumes every device survives every round; real edge
fleets do not (Efficient Split Federated Learning, arXiv:2504.14667). This
module is the single source of fault realizations for *both* fleet engines
and the live protocol:

  ``FaultModel.realize`` — seeded per-(round, device) arrays: dropout
      (device misses the round), straggler slowdown factors on device
      compute and on the radio link, mid-round link outages, and a
      join/leave membership trajectory (two-state Markov chain).
      Composable with ``channel.draw_channel_matrix``: realizations are
      drawn once, array-shaped, from per-device streams that are disjoint
      from the channel streams, so the scalar and vectorized engines — and
      a protocol run over the same fleet — consume identical faults.

  ``RetryPolicy`` / ``retry_call`` — capped exponential backoff with a
      cumulative timeout budget, for the activation/gradient exchange.

  ``CircuitBreaker`` — evicts repeat offenders for a cool-down window
      (half-open after the cool-down expires).

  ``FaultInjector`` — turns a realization into deterministic
      ``LinkTimeout`` raises for the live protocol (dropout = the link is
      dead all round; outage = the first attempt(s) fail, retries succeed).

Zero-probability faults are exactly the identity: all devices active, no
dropouts, every slowdown factor exactly 1.0 — the degenerate case is
bit-identical to a fault-free simulation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

#: third element of the per-device seed sequence — keeps fault streams
#: disjoint from the channel streams even when both use the same base seed
_FAULT_STREAM = 0xFA


class LinkTimeout(TimeoutError):
    """One activation/gradient exchange attempt timed out (injectable)."""


class ExchangeFailed(RuntimeError):
    """All retries for one exchange exhausted — the device drops the round."""

    def __init__(self, msg: str, *, attempts: int, backoff_s: float):
        super().__init__(msg)
        self.attempts = attempts
        self.backoff_s = backoff_s


# ---------------------------------------------------------------------------
# Fault realization (arrays, shared by both engines)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultRealization:
    """Per-(round, device) fault draws; every array is ``(rounds, devices)``.

    Slowdown factors are exactly 1.0 where no straggler event fired, so a
    zero-probability model leaves delays bit-identical.
    """
    active: np.ndarray            # bool — device is a fleet member this round
    dropout: np.ndarray           # bool — member, but misses the round
    compute_slowdown: np.ndarray  # float >= 1 on device compute
    link_slowdown: np.ndarray     # float >= 1 on uplink/downlink time
    outage: np.ndarray            # bool — mid-round link outage (stall)
    outage_stall_s: float = 1.0   # retransmission stall per outage

    @property
    def rounds(self) -> int:
        return self.active.shape[0]

    @property
    def n_devices(self) -> int:
        return self.active.shape[1]

    @property
    def participating(self) -> np.ndarray:
        """Members that actually start the round (active and not dropped)."""
        return self.active & ~self.dropout

    def realized_delays(self, d_device: np.ndarray, d_uplink: np.ndarray,
                        d_server: np.ndarray,
                        d_downlink: np.ndarray) -> np.ndarray:
        """Nominal per-component delays -> delays the fleet experiences.

        Stragglers stretch the device-compute and radio terms; the server
        term is unaffected (the server does not straggle); an outage adds a
        fixed retransmission stall on top.
        """
        return (d_device * self.compute_slowdown
                + (d_uplink + d_downlink) * self.link_slowdown
                + d_server
                + np.where(self.outage, self.outage_stall_s, 0.0))

    def to_jsonable(self) -> Dict:
        return {
            "schema": "fault-realization/v1",
            "rounds": int(self.rounds),
            "devices": int(self.n_devices),
            "active": self.active.astype(int).tolist(),
            "dropout": self.dropout.astype(int).tolist(),
            "compute_slowdown": self.compute_slowdown.tolist(),
            "link_slowdown": self.link_slowdown.tolist(),
            "outage": self.outage.astype(int).tolist(),
        }


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Seeded generator of :class:`FaultRealization` arrays.

    Probabilities are per (round, device); the membership trajectory is a
    two-state Markov chain (present -> absent with ``leave_prob``, absent ->
    present with ``rejoin_prob``), the rest are i.i.d. draws. Each device
    consumes its own PRNG stream (``[seed, device, _FAULT_STREAM]``), so
    realizations are stable under changes to the fleet size and never alias
    the channel fading streams.
    """
    dropout_prob: float = 0.0
    straggler_prob: float = 0.0
    slowdown_min: float = 1.5     # uniform slowdown factor range when a
    slowdown_max: float = 4.0     # straggler event fires
    outage_prob: float = 0.0
    outage_stall_s: float = 1.0   # retransmission stall per outage
    leave_prob: float = 0.0
    rejoin_prob: float = 0.5
    initial_absent_prob: float = 0.0

    def __post_init__(self):
        for name in ("dropout_prob", "straggler_prob", "outage_prob",
                     "leave_prob", "rejoin_prob", "initial_absent_prob"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p!r}")
        if not 1.0 <= self.slowdown_min <= self.slowdown_max:
            raise ValueError("need 1 <= slowdown_min <= slowdown_max, got "
                             f"({self.slowdown_min}, {self.slowdown_max})")

    @property
    def mean_slowdown(self) -> float:
        """E[slowdown | straggler] — what the deadline objective plans for."""
        return 0.5 * (self.slowdown_min + self.slowdown_max)

    def realize(self, rounds: int, n_devices: int, *,
                seed: int = 0) -> FaultRealization:
        active = np.empty((rounds, n_devices), bool)
        dropout = np.empty((rounds, n_devices), bool)
        comp = np.ones((rounds, n_devices))
        link = np.ones((rounds, n_devices))
        outage = np.empty((rounds, n_devices), bool)
        for m in range(n_devices):
            rng = np.random.default_rng([seed, m, _FAULT_STREAM])
            # fixed draw count per device regardless of the path taken, so
            # realizations are reproducible prefix-stable in `rounds`
            present = rng.random() >= self.initial_absent_prob
            u = rng.random((rounds, 4))         # leave/rejoin, drop, strag, out
            factors = rng.uniform(self.slowdown_min, self.slowdown_max,
                                  size=(rounds, 2))
            for r in range(rounds):
                if present:
                    present = u[r, 0] >= self.leave_prob
                else:
                    present = u[r, 0] < self.rejoin_prob
                active[r, m] = present
            dropout[:, m] = u[:, 1] < self.dropout_prob
            straggler = u[:, 2] < self.straggler_prob
            comp[straggler, m] = factors[straggler, 0]
            link[straggler, m] = factors[straggler, 1]
            outage[:, m] = u[:, 3] < self.outage_prob
        return FaultRealization(active=active, dropout=dropout,
                                compute_slowdown=comp, link_slowdown=link,
                                outage=outage,
                                outage_stall_s=self.outage_stall_s)


@dataclasses.dataclass(frozen=True)
class DeadlinePolicy:
    """How the server closes a round under churn.

    ``quantile`` — the round deadline is this quantile of the *predicted*
    (nominal decision) delays across the round's members; devices whose
    realized delay exceeds it are marked late and dropped from the round.
    ``objective_deadline_s`` — when set, CARD's objective is penalized by
    ``objective_penalty * P(miss the deadline)`` so the (cut, f) decision
    itself accounts for straggler/dropout risk (see ``card.DeadlineSpec``).
    """
    quantile: float = 0.9
    objective_deadline_s: Optional[float] = None
    objective_penalty: float = 1.0

    def __post_init__(self):
        if not 0.0 < self.quantile <= 1.0:
            raise ValueError(f"quantile must be in (0, 1], got "
                             f"{self.quantile!r}")


# ---------------------------------------------------------------------------
# Retry / circuit-breaker primitives (protocol + trainer hardening)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with a cumulative per-exchange budget."""
    max_attempts: int = 3
    base_backoff_s: float = 0.05
    max_backoff_s: float = 1.0
    timeout_s: float = 30.0       # total budget across attempts + backoff

    def backoff_s(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based: after first failure)."""
        return min(self.base_backoff_s * (2.0 ** (attempt - 1)),
                   self.max_backoff_s)


def retry_call(fn: Callable[[], object], policy: RetryPolicy, *,
               retry_on: Tuple[type, ...] = (LinkTimeout, OSError),
               sleep: Optional[Callable[[float], None]] = None,
               clock: Optional[Callable[[], float]] = None):
    """Run ``fn`` under ``policy``; returns ``(result, attempts, backoff_s)``.

    ``sleep`` defaults to pure accounting (no wall-clock sleep — the
    simulated cost model owns time); pass ``time.sleep`` for real I/O.
    ``clock`` (monotonic seconds) enforces the cumulative timeout budget.
    Raises :class:`ExchangeFailed` when attempts or budget are exhausted.
    """
    total_backoff_s = 0.0
    start = clock() if clock else None
    last: Optional[BaseException] = None
    for attempt in range(1, policy.max_attempts + 1):
        try:
            return fn(), attempt, total_backoff_s
        except retry_on as e:  # noqa: PERF203 — retry loop is the point
            last = e
            if attempt == policy.max_attempts:
                break
            pause_s = policy.backoff_s(attempt)
            elapsed_s = (clock() - start) if clock else total_backoff_s
            if elapsed_s + pause_s > policy.timeout_s:
                raise ExchangeFailed(
                    f"timeout budget {policy.timeout_s}s exhausted after "
                    f"{attempt} attempt(s): {e}",
                    attempts=attempt, backoff_s=total_backoff_s) from e
            total_backoff_s += pause_s
            if sleep is not None:
                sleep(pause_s)
    raise ExchangeFailed(
        f"all {policy.max_attempts} attempts failed: {last}",
        attempts=policy.max_attempts, backoff_s=total_backoff_s) from last


class CircuitBreaker:
    """Per-device breaker: repeated failures evict a device for a cool-down.

    Closed (normal) -> open after ``failure_threshold`` *consecutive*
    failures; open blocks the device for ``cooldown_rounds`` rounds, then
    half-opens (one probe allowed; a failure re-opens immediately).
    """

    def __init__(self, *, failure_threshold: int = 3,
                 cooldown_rounds: int = 5):
        if failure_threshold < 1 or cooldown_rounds < 1:
            raise ValueError("failure_threshold and cooldown_rounds must "
                             "be >= 1")
        self.failure_threshold = failure_threshold
        self.cooldown_rounds = cooldown_rounds
        self._failures: Dict[int, int] = {}
        self._open_until: Dict[int, int] = {}

    def allow(self, device_idx: int, round_idx: int) -> bool:
        return round_idx >= self._open_until.get(device_idx, -1)

    def is_open(self, device_idx: int, round_idx: int) -> bool:
        return not self.allow(device_idx, round_idx)

    def record_success(self, device_idx: int) -> None:
        self._failures[device_idx] = 0
        self._open_until.pop(device_idx, None)

    def record_failure(self, device_idx: int, round_idx: int) -> None:
        n = self._failures.get(device_idx, 0) + 1
        self._failures[device_idx] = n
        if n >= self.failure_threshold:
            self._open_until[device_idx] = round_idx + 1 + self.cooldown_rounds
            # half-open: the probe after the cool-down only needs one more
            # failure to re-open
            self._failures[device_idx] = self.failure_threshold - 1

    def evicted(self, round_idx: int) -> List[int]:
        return sorted(d for d, until in self._open_until.items()
                      if round_idx < until)


@dataclasses.dataclass
class FaultInjector:
    """Deterministic link faults for the live protocol, from a realization.

    Dropout / inactive membership: every attempt in that round raises
    (the device is unreachable). Outage: the first
    ``outage_fail_attempts`` attempts raise, then the link recovers —
    exactly the case retries exist for. Rounds beyond the realization wrap
    around (long protocol runs on a short realization).
    """
    realization: FaultRealization
    outage_fail_attempts: int = 1

    def check(self, round_idx: int, device_idx: int, attempt: int) -> None:
        r = round_idx % self.realization.rounds
        if not self.realization.active[r, device_idx]:
            raise LinkTimeout(f"device {device_idx} left the fleet "
                              f"(round {round_idx})")
        if self.realization.dropout[r, device_idx]:
            raise LinkTimeout(f"device {device_idx} dropped round "
                              f"{round_idx}")
        if self.realization.outage[r, device_idx] \
                and attempt <= self.outage_fail_attempts:
            raise LinkTimeout(f"link outage on device {device_idx}, round "
                              f"{round_idx}, attempt {attempt}")

    def is_member(self, round_idx: int, device_idx: int) -> bool:
        r = round_idx % self.realization.rounds
        return bool(self.realization.active[r, device_idx])
