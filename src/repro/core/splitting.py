"""Split execution — the SL computation itself, in JAX (Sec. II-B stages 3-4).

The device stage (embedding + layers [0,c) + its LoRA adapters) and the
server stage (layers [c,I) + final norm + head + loss + its adapters) are
separate jitted functions bridged by ``jax.vjp``:

  device:  smashed = f_D(lora_D; x)                        (Eq. 2)
  channel: smashed' = Q(smashed)         phi-compression (int8 quantization)
  server:  loss, d(smashed'), grads_S = f_S(lora_S; smashed', y)   (Eq. 3-4)
  channel: g' = Q(d smashed')
  device:  grads_D = vjp_D(g')                              (Eq. 5)

The compression is a straight-through int8 quantizer: the paper models phi
as a data-size ratio on the link (Eq. 9); here it is also *executed* so the
training dynamics include the quantization error.

A cut is a static argument — each cut compiles its own pair of programs and
``SplitExecutor`` memoizes them (cut changes at round granularity, Alg. 1).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.models.common import Params, softmax_cross_entropy


# ---------------------------------------------------------------------------
# Channel compression (phi)
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-last-axis int8 quantization of smashed activations:
    returns (int8 values shaped like ``x``, float32 scales with the last
    axis kept as size 1)."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def channel_compress(x: jax.Array, enabled: bool) -> jax.Array:
    """Straight-through int8 round trip emulating the phi-compressed link."""
    if not enabled:
        return x
    q, s = quantize_int8(x)
    xq = dequantize_int8(q, s, x.dtype)
    return x + jax.lax.stop_gradient(xq - x)


# ---------------------------------------------------------------------------
# Stage functions
# ---------------------------------------------------------------------------


def split_lora(lora: Params, cut: int) -> Tuple[Params, Params]:
    """R = {R^D ; R^S} (Eq. 6, inverse): split adapters at the cut."""
    dev = {"layers": model_lib.slice_layers(lora["layers"], 0, cut)}
    n = jax.tree_util.tree_leaves(lora["layers"])[0].shape[0]
    srv = {"layers": model_lib.slice_layers(lora["layers"], cut, n)}
    return dev, srv


def merge_lora(dev: Params, srv: Params) -> Params:
    """Stage 5, Eq. 6: R = {R^{D,T} ; R^{S,T}}."""
    merged = jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b], axis=0),
        dev["layers"], srv["layers"])
    return {"layers": merged}


def device_forward(frozen: Params, lora_dev: Params, inputs: jax.Array,
                   cfg: ModelConfig, cut: int, *, impl: str = "naive",
                   compress: bool = True) -> jax.Array:
    """Eq. 2: smashed data at the cut layer (embedding + layers [0,c))."""
    if cut == 0:
        x = model_lib.embed_inputs(frozen, inputs, cfg)
    else:
        lora_full = {"layers": lora_dev["layers"]}
        x, _ = model_lib.forward_hidden(
            frozen, lora_full, inputs, cfg, lo=0, hi=cut, impl=impl,
            remat=False, lora_sliced=True)
    return channel_compress(x, compress)


def server_loss(frozen: Params, lora_srv: Params, smashed: jax.Array,
                labels: jax.Array, cfg: ModelConfig, cut: int, *,
                impl: str = "naive") -> jax.Array:
    """Eq. 3 + loss: layers [c,I) + final norm + head + CE."""
    if cut == cfg.n_layers:
        x, aux = smashed, 0.0
    else:
        lora_full = {"layers": lora_srv["layers"]}
        x, aux = model_lib.forward_hidden(
            frozen, lora_full, smashed, cfg, lo=cut, hi=cfg.n_layers,
            impl=impl, remat=False, inputs_embedded=True, lora_sliced=True)
    logits = model_lib.logits_from_hidden(frozen, x, cfg)
    return softmax_cross_entropy(logits, labels) + aux


# ---------------------------------------------------------------------------
# One split fine-tuning step (stages 3-4)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "cut", "impl", "compress"))
def split_grads(frozen: Params, lora_dev: Params, lora_srv: Params,
                inputs: jax.Array, labels: jax.Array, *, cfg: ModelConfig,
                cut: int, impl: str = "naive", compress: bool = True
                ) -> Tuple[jax.Array, Params, Params]:
    """Returns (loss, grads_dev, grads_srv) with the smashed-data gradient
    crossing the (compressed) channel boundary — exactly stages 3-4."""
    # --- device-side FP, with vjp captured for the later BP ----------------
    def dev_fn(ld):
        return device_forward(frozen, ld, inputs, cfg, cut, impl=impl,
                              compress=compress)

    smashed, dev_vjp = jax.vjp(dev_fn, lora_dev)

    # --- uplink: smashed data + labels (compression already applied) -------
    # --- server-side FP + BP ------------------------------------------------
    def srv_fn(ls, sm):
        return server_loss(frozen, ls, sm, labels, cfg, cut, impl=impl)

    loss, srv_vjp = jax.vjp(srv_fn, lora_srv, smashed)
    grads_srv, g_smashed = srv_vjp(jnp.ones((), loss.dtype))

    # --- downlink: smashed-data gradient, phi-compressed --------------------
    g_smashed = channel_compress(g_smashed, compress)

    # --- device-side BP ------------------------------------------------------
    (grads_dev,) = dev_vjp(g_smashed)
    return loss, grads_dev, grads_srv


class SplitExecutor:
    """Caches compiled split programs per cut (Stage 1 re-splits per round)."""

    def __init__(self, cfg: ModelConfig, *, impl: str = "naive",
                 compress: bool = True):
        self.cfg = cfg
        self.impl = impl
        self.compress = compress

    def step(self, frozen: Params, lora: Params, batch: Dict[str, Any],
             cut: int) -> Tuple[jax.Array, Params]:
        """One local epoch: returns (loss, full-model LoRA grads)."""
        lora_dev, lora_srv = split_lora(lora, cut)
        inputs = (batch["embeds"] if self.cfg.input_mode == "embeds"
                  else batch["tokens"])
        loss, g_dev, g_srv = split_grads(
            frozen, lora_dev, lora_srv, inputs, batch["labels"],
            cfg=self.cfg, cut=cut, impl=self.impl, compress=self.compress)
        return loss, merge_lora(g_dev, g_srv)
