"""Hardware profiles: the paper's edge fleet (Table I), simulation constants
(Table II), and the TPU-v5e server profile used for the multi-pod mapping.

The paper's throughput model: a processor sustains ``f * delta * sigma``
FLOP/s (GPU frequency x FLOPs/core/cycle x cores), Eq. (7)-(8). Server power
is cubic in frequency, ``P = xi * f^3`` (Sec. III-B).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Tuple

import numpy as np

GIGA = 1e9


@dataclass(frozen=True)
class DeviceProfile:
    """An edge device (or the server) in the paper's cost model."""
    name: str
    platform: str
    f_max: float          # max GPU frequency, Hz
    delta: float          # FLOPs per core per cycle
    sigma: int            # cores
    f_min: float = 0.0    # min frequency (server DVFS lower bound)
    xi: float = 1e-25     # power coefficient, Watt/(cycle/s)^3 (server only)
    mem_bytes: float = 8e9  # device RAM (feasibility mask for huge backbones)

    @property
    def peak_flops(self) -> float:
        return self.f_max * self.delta * self.sigma

    def throughput(self, f: float) -> float:
        return f * self.delta * self.sigma

    def power(self, f: float) -> float:
        return self.xi * f ** 3


# --- Table I ---------------------------------------------------------------

SERVER_RTX4060TI = DeviceProfile(
    name="server", platform="Nvidia RTX 4060Ti",
    f_max=2.46 * GIGA, delta=2.0, sigma=3072, f_min=0.3 * GIGA,
    xi=1e-25, mem_bytes=16e9)

EDGE_FLEET: Tuple[DeviceProfile, ...] = (
    DeviceProfile("device1", "Jetson AGX Orin", 1.3 * GIGA, 2.0, 2048,
                  mem_bytes=32e9),
    DeviceProfile("device2", "Jetson AGX Orin", 1.0 * GIGA, 2.0, 2048,
                  mem_bytes=32e9),
    DeviceProfile("device3", "Jetson AGX Orin", 0.7 * GIGA, 2.0, 1792,
                  mem_bytes=16e9),
    DeviceProfile("device4", "Jetson Orin NX", 0.7 * GIGA, 2.0, 1024,
                  mem_bytes=8e9),
    DeviceProfile("device5", "Jetson AGX Nano", 0.5 * GIGA, 2.0, 512,
                  mem_bytes=4e9),
)


def make_heterogeneous_fleet(n: int, *, seed: int = 0,
                             templates: Tuple[DeviceProfile, ...] = EDGE_FLEET
                             ) -> Tuple[DeviceProfile, ...]:
    """An ``n``-device fleet for scale sweeps: each device is one of the
    Table-I edge platforms with its GPU frequency jittered +-20% (DVFS bins,
    thermal throttling) — the "massive mobile devices" population the paper
    targets, heterogeneous in both platform and clock."""
    rng = np.random.default_rng(seed)
    kinds = rng.integers(0, len(templates), size=n)
    scales = rng.uniform(0.8, 1.2, size=n)
    fleet = []
    for i in range(n):
        t = templates[int(kinds[i])]
        fleet.append(replace(t, name=f"device{i + 1}",
                             f_max=t.f_max * float(scales[i])))
    return tuple(fleet)


# --- Server tier (hierarchical multi-server SL, cf. SplitLLM) --------------


@dataclass(frozen=True)
class ServerTier:
    """A tier of edge servers behind one aggregator (hierarchical SL).

    The paper models a single edge server; SplitLLM (arXiv:2501.13318)
    formulates the tier: each device is assigned to one server, every
    server runs its own DVFS range (``DeviceProfile.f_min``/``f_max``),
    hosts at most ``capacity[s]`` devices per round, and forwards its
    aggregated LoRA adapters to the cloud aggregator over a backhaul link
    of ``backhaul_bits_per_s[s]`` (bit/s).

    ``hierarchical_card`` (``core/card.py``) decides device→server
    assignment against this structure; ``TieredRoundContext``
    (``core/cost_model.py``) broadcasts Eqs. 7-12 over the extra server
    axis.
    """
    servers: Tuple[DeviceProfile, ...]
    capacity: Tuple[int, ...]
    backhaul_bits_per_s: Tuple[float, ...]

    def __post_init__(self):
        if not self.servers:
            raise ValueError("a ServerTier needs at least one server")
        if len(self.capacity) != len(self.servers) \
                or len(self.backhaul_bits_per_s) != len(self.servers):
            raise ValueError(
                f"per-server fields must match len(servers)={len(self.servers)}"
                f": capacity={len(self.capacity)}, "
                f"backhaul={len(self.backhaul_bits_per_s)}")
        if any(c < 1 for c in self.capacity):
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")
        if any(not (b > 0) for b in self.backhaul_bits_per_s):
            raise ValueError("backhaul_bits_per_s must be positive, got "
                             f"{self.backhaul_bits_per_s}")

    @property
    def n_servers(self) -> int:
        return len(self.servers)

    @property
    def total_capacity(self) -> int:
        return sum(self.capacity)


def make_server_tier(n: int, *, base: DeviceProfile = SERVER_RTX4060TI,
                     capacity: int = 1000,
                     backhaul_bits_per_s: float = 1e9,
                     seed: int = 0) -> ServerTier:
    """An ``n``-server tier for hierarchy sweeps: each server is the base
    profile with its clock jittered +-20% (heterogeneous provisioning) and
    its backhaul jittered +-50%, seeded like ``make_heterogeneous_fleet``."""
    rng = np.random.default_rng(seed)
    f_scales = rng.uniform(0.8, 1.2, size=n)
    b_scales = rng.uniform(0.5, 1.5, size=n)
    servers = tuple(replace(base, name=f"server{s + 1}",
                            f_max=base.f_max * float(f_scales[s]))
                    for s in range(n))
    return ServerTier(servers=servers, capacity=(capacity,) * n,
                      backhaul_bits_per_s=tuple(
                          backhaul_bits_per_s * float(b) for b in b_scales))


def tier_arrays(tier: ServerTier) -> Dict[str, "object"]:
    """Stack per-server scalars into numpy arrays for the tiered engine."""
    return {
        "tp_per_hz": np.array([s.delta * s.sigma for s in tier.servers],
                              np.float64),
        "f_max": np.array([s.f_max for s in tier.servers], np.float64),
        "f_min": np.array([s.f_min for s in tier.servers], np.float64),
        "capacity": np.array(tier.capacity, np.int64),
        "backhaul_bits_per_s": np.array(tier.backhaul_bits_per_s, np.float64),
    }


def profile_from_throughput(name: str, flops_per_s: float, *,
                            f_max: float = 1.0 * GIGA,
                            **kwargs) -> DeviceProfile:
    """Express a *measured* sustained throughput in the paper's
    ``f * delta * sigma`` algebra (one core, delta = FLOPs/cycle at
    ``f_max``), so a roofline-fitted host slots into CARD's closed form as
    a device or server profile unchanged."""
    if flops_per_s <= 0 or not np.isfinite(flops_per_s):
        raise ValueError(f"need a positive finite throughput, got "
                         f"{flops_per_s!r}")
    return DeviceProfile(name=name, platform="measured", f_max=f_max,
                         delta=flops_per_s / f_max, sigma=1, **kwargs)


def fleet_arrays(devices) -> Dict[str, "object"]:
    """Stack per-device scalars into numpy arrays for the batched engine."""
    return {
        "peak_flops": np.array([d.peak_flops for d in devices], np.float64),
        "mem_bytes": np.array([d.mem_bytes for d in devices], np.float64),
    }


# --- TPU v5e server profile (multi-pod mapping, DESIGN.md §3) --------------
# The paper's continuous f^S maps to allocated server throughput. One v5e
# chip: 197 TFLOP/s bf16. We express it in the same (f, delta, sigma) algebra
# so CARD's closed form applies unchanged.

TPU_V5E_CHIP = DeviceProfile(
    name="tpu-v5e", platform="TPU v5e chip",
    f_max=0.94 * GIGA, delta=8.0, sigma=26_214,  # 0.94e9*8*26214 ~= 197e12
    f_min=0.1 * GIGA, xi=2.4e-25, mem_bytes=16e9)

TPU_V5E_HBM_BW = 819e9        # bytes/s
TPU_V5E_ICI_BW = 50e9         # bytes/s per link
TPU_V5E_PEAK_BF16 = 197e12    # FLOP/s


def tpu_pod_profile(chips: int) -> DeviceProfile:
    """A pod slice as one 'server' in the paper's algebra."""
    return replace(TPU_V5E_CHIP, name=f"tpu-v5e-x{chips}",
                   sigma=TPU_V5E_CHIP.sigma * chips,
                   mem_bytes=16e9 * chips)


# --- Table II ---------------------------------------------------------------

@dataclass(frozen=True)
class SimParams:
    """Simulation constants (paper Table II): Eq. 12 weights, compression
    ratios, payload precisions in bytes, and radio parameters (bandwidth
    in Hz, transmit powers in dBm)."""
    xi: float = 1e-25          # server power coefficient
    w: float = 0.2             # delay weight in Eq. (12)
    local_epochs: int = 5      # T_{m,n}
    phi: float = 0.1           # smashed-data/gradient compression ratio
    act_bytes: int = 2         # bf16 activations
    adapter_bytes: int = 4     # fp32 LoRA adapters
    bandwidth_hz: float = 20e6           # per-device allocation
    tx_power_dbm_up: float = 23.0        # device uplink
    tx_power_dbm_down: float = 30.0      # AP downlink
    noise_dbm_per_hz: float = -174.0
    mini_batch: int = 4
    seq_len: int = 512


DEFAULT_SIM = SimParams()
