"""Measured cost model: kernel timing probes -> roofline fit -> LatencyTable.

Closes the ROADMAP loop "Pallas kernel optimization loop feeding CARD": the
repo ships real kernels (``kernels/lora_matmul.py``, ``flash_attention.py``,
``ssd_scan.py``) and CARD decisions that, until now, rested purely on the
paper's analytic FLOP counts.  This module is the bridge:

  1. ``probe_kernels``     — wall-time the kernels (and their compiled jnp
                             references) at a ladder of shapes, recording
                             (FLOPs, HBM bytes, seconds) per probe;
  2. ``fit_roofline``      — least-squares fit of the two-term roofline
                             ``t = flops / C + bytes / B`` (the same model
                             ``benchmarks/roofline.py`` renders for the
                             dry-run records) to the probe samples;
  3. ``LatencyTable``      — per-architecture per-layer forward latencies
                             predicted from the fit (or synthesized from
                             the analytic model), the pluggable backend
                             ``cost_model.RoundContext`` /
                             ``BatchedRoundContext`` consume via
                             ``cost_source="measured"``.

The currency trick: a ``LatencyTable`` stores *seconds at a reference
throughput*; ``TableCompute`` converts them back into **effective FLOPs**
(``seconds * ref_throughput``), so every downstream equation of the paper
(Eqs. 7, 8, 11 and the closed-form Eq. 16 frequency) applies unchanged.
Measured tables inflate effective FLOPs by exactly the achieved-efficiency
gap (1/MFU) the roofline fit observed — bandwidth-bound layers cost more
than their FLOP count says, which is precisely what moves CARD's cut.

On CPU hosts the Pallas kernels only run in ``interpret=True`` mode (a
Python-level emulation — orders of magnitude off real silicon), so the
default probe backend is the *compiled* jnp reference path; on a TPU
backend the Pallas kernels themselves are probed.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import cached_property
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, ModelConfig, get_config
from repro.core.cost_model import (LORA_TRAIN_FACTOR, Workload,
                                   embed_fwd_flops_per_token,
                                   head_fwd_flops_per_token,
                                   layer_fwd_flops_per_token)

#: serialization schema tag for latency tables embedded in BENCH_*.json
LATENCY_TABLE_SCHEMA = "latency-table/v1"


# ---------------------------------------------------------------------------
# Timing probes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ProbeResult:
    """One timed kernel invocation with its roofline coordinates."""
    kernel: str        # lora_matmul | flash_attention | ssd_scan
    backend: str       # "jnp" (compiled reference) | "pallas" (interpret/TPU)
    shape: str         # human-readable shape tag
    flops: float       # analytic FLOPs of the call
    hbm_bytes: float   # bytes moved between HBM and compute (inputs+outputs)
    seconds: float     # best-of-reps wall time

    def to_dict(self) -> Dict:
        return {"kernel": self.kernel, "backend": self.backend,
                "shape": self.shape, "flops": self.flops,
                "hbm_bytes": self.hbm_bytes, "seconds": self.seconds}


def _time_call(fn: Callable, reps: int) -> float:
    """Best-of-reps wall time; one untimed call pays compile/warmup."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        # splint: ignore[trace-safety] -- timing probe: the sync IS the point
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _lora_probe(m: int, k: int, n: int, r: int, backend: str):
    from repro.kernels import ops, ref
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(keys[0], (m, k), jnp.float32)
    w = jax.random.normal(keys[1], (k, n), jnp.float32)
    a = jax.random.normal(keys[2], (k, r), jnp.float32)
    b = jax.random.normal(keys[3], (r, n), jnp.float32)
    # inputs are passed as arguments (not closed over) so XLA cannot
    # constant-fold the whole probe away at trace time
    if backend == "pallas":
        call = ops.lora_matmul
    else:
        call = jax.jit(ref.lora_matmul_ref)
    fn = lambda: call(x, w, a, b, 2.0)  # noqa: E731
    flops = 2 * m * k * n + 2 * m * k * r + 2 * m * r * n
    bytes_ = 4 * (m * k + k * n + k * r + r * n + m * n)
    return fn, float(flops), float(bytes_)


def _attention_probe(b: int, s: int, hq: int, hkv: int, d: int, backend: str):
    from repro.kernels import ops
    from repro.models.attention import chunked_attention
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (b, s, hq, d), jnp.float32)
    k = jax.random.normal(keys[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(keys[2], (b, s, hkv, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    if backend == "pallas":
        fn = lambda: ops.flash_attention(q, k, v)  # noqa: E731
    else:
        call = jax.jit(lambda q_, k_, v_, p_: chunked_attention(
            q_, k_, v_, causal=True, window=0, q_positions=p_,
            k_positions=p_))
        fn = lambda: call(q, k, v, pos)  # noqa: E731
    # causal scores + weighted sum: 2 matmuls x (S^2/2) x D per (b, hq)
    flops = 2.0 * b * hq * s * s * d
    bytes_ = 4.0 * (b * s * hq * d * 2 + b * s * hkv * d * 2)
    return fn, flops, bytes_


def _ssd_probe(b: int, length: int, nh: int, hp: int, ns: int, chunk: int,
               backend: str):
    from repro.kernels import ops
    from repro.models.mamba import ssd_chunked
    keys = jax.random.split(jax.random.PRNGKey(2), 4)
    xt = jax.random.normal(keys[0], (b, length, nh, hp)) * 0.2
    a = -jnp.abs(jax.random.normal(keys[1], (b, length, nh))) * 0.1
    B = jax.random.normal(keys[2], (b, length, ns)) * 0.3
    C = jax.random.normal(keys[3], (b, length, ns)) * 0.3
    if backend == "pallas":
        fn = lambda: ops.ssd_scan(xt, a, B, C, chunk)  # noqa: E731
    else:
        call = jax.jit(ssd_chunked, static_argnums=(4,))
        fn = lambda: call(xt, a, B, C, chunk)  # noqa: E731
    di = nh * hp
    flops = float(b * length) * (2.0 * chunk * di + 4.0 * di * ns)
    bytes_ = 4.0 * b * length * (2 * nh * hp + nh + 2 * ns)
    return fn, flops, bytes_


# shape ladders: varied size and arithmetic intensity so the compute,
# bandwidth, and per-call-overhead terms are separable in the fit (the tiny
# shapes pin the overhead intercept; the large ones pin compute)
_SMOKE_SHAPES: Tuple[Tuple[str, str, tuple], ...] = (
    ("lora_matmul", "128x128x128r8", (128, 128, 128, 8)),
    ("lora_matmul", "256x256x256r8", (256, 256, 256, 8)),
    ("lora_matmul", "512x512x512r16", (512, 512, 512, 16)),
    ("flash_attention", "b1s128h4", (1, 128, 4, 2, 32)),
    ("flash_attention", "b1s256h4", (1, 256, 4, 2, 32)),
    ("flash_attention", "b1s512h4", (1, 512, 4, 2, 32)),
    ("ssd_scan", "l128c32", (1, 128, 4, 32, 16, 32)),
    ("ssd_scan", "l256c64", (1, 256, 4, 32, 16, 64)),
)

_FULL_SHAPES: Tuple[Tuple[str, str, tuple], ...] = _SMOKE_SHAPES + (
    ("lora_matmul", "1024x1024x1024r16", (1024, 1024, 1024, 16)),
    ("lora_matmul", "256x1024x512r16", (256, 1024, 512, 16)),
    ("flash_attention", "b1s512h8", (1, 512, 8, 4, 64)),
    ("ssd_scan", "l512c128", (1, 512, 4, 64, 64, 128)),
)

_BUILDERS = {"lora_matmul": _lora_probe, "flash_attention": _attention_probe,
             "ssd_scan": _ssd_probe}


def default_probe_backend() -> str:
    """Compiled jnp references on CPU/GPU; real Pallas kernels on TPU."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def probe_kernels(*, mode: str = "smoke", backend: Optional[str] = None,
                  reps: int = 3) -> List[ProbeResult]:
    """Time the kernel ladder; returns one ``ProbeResult`` per shape."""
    backend = backend or default_probe_backend()
    shapes = _SMOKE_SHAPES if mode == "smoke" else _FULL_SHAPES
    out = []
    for kernel, tag, args in shapes:
        fn, flops, bytes_ = _BUILDERS[kernel](*args, backend)
        out.append(ProbeResult(kernel=kernel, backend=backend, shape=tag,
                               flops=flops, hbm_bytes=bytes_,
                               seconds=_time_call(fn, reps)))
    return out


# ---------------------------------------------------------------------------
# Roofline fit: t = flops / C + bytes / B
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RooflineFit:
    """Host roofline fitted from probes.

    ``t = overhead_s + flops * inv_compute + bytes * inv_bandwidth`` — the
    two-term roofline of ``benchmarks/roofline.py`` plus a per-call launch
    overhead intercept (without it, small-shape probes poison the slopes).
    ``achieved_flops_per_s`` (best observed FLOPs rate across probes) is
    the fallback currency when the compute slope is not identifiable on a
    bandwidth-bound host.
    """
    inv_compute: float     # seconds per FLOP (1/C)
    inv_bandwidth: float   # seconds per byte (1/B)
    overhead_s: float      # per-call launch/dispatch overhead
    achieved_flops_per_s: float
    rel_residual: float    # ||t_pred - t|| / ||t|| over the fit samples
    n_probes: int
    backend: str

    @property
    def compute_flops_per_s(self) -> float:
        return 1.0 / self.inv_compute if self.inv_compute > 0 else float("inf")

    @property
    def bandwidth_bytes_per_s(self) -> float:
        return (1.0 / self.inv_bandwidth if self.inv_bandwidth > 0
                else float("inf"))

    @property
    def ref_throughput(self) -> float:
        """Finite FLOP/s currency for latency tables: the fitted sustained
        compute rate, or the best achieved rate when compute never bound."""
        if self.inv_compute > 0:
            return self.compute_flops_per_s
        return self.achieved_flops_per_s

    def predict(self, flops: float, hbm_bytes: float) -> float:
        """Roofline-model seconds for a call of the given footprint."""
        return (self.overhead_s + flops * self.inv_compute
                + hbm_bytes * self.inv_bandwidth)

    def to_dict(self) -> Dict:
        return {"inv_compute_s_per_flop": self.inv_compute,
                "inv_bandwidth_s_per_byte": self.inv_bandwidth,
                "overhead_s": self.overhead_s,
                "achieved_flops_per_s": self.achieved_flops_per_s,
                "compute_flops_per_s": self.compute_flops_per_s,
                "bandwidth_bytes_per_s": self.bandwidth_bytes_per_s,
                "rel_residual": self.rel_residual,
                "n_probes": self.n_probes, "backend": self.backend}

    @classmethod
    def from_dict(cls, d: Dict) -> "RooflineFit":
        return cls(inv_compute=d["inv_compute_s_per_flop"],
                   inv_bandwidth=d["inv_bandwidth_s_per_byte"],
                   overhead_s=d.get("overhead_s", 0.0),
                   achieved_flops_per_s=d.get("achieved_flops_per_s", 0.0),
                   rel_residual=d.get("rel_residual", 0.0),
                   n_probes=d.get("n_probes", 0),
                   backend=d.get("backend", "unknown"))


def _nnls(A: np.ndarray, t: np.ndarray) -> np.ndarray:
    """Tiny active-set NNLS: drop negative coefficients and refit until all
    survivors are nonnegative (at most ``A.shape[1]`` iterations)."""
    active = list(range(A.shape[1]))
    coef = np.zeros(A.shape[1])
    while active:
        c, *_ = np.linalg.lstsq(A[:, active], t, rcond=None)
        if (c >= 0).all():
            coef[:] = 0.0
            coef[active] = c
            return coef
        active.pop(int(np.argmin(c)))
    return coef


def fit_roofline(probes: Sequence[ProbeResult]) -> RooflineFit:
    """Nonnegative least squares of ``t = t0 + a*flops + b*bytes``.

    Rows are weighted by 1/t (relative error): probe times span orders of
    magnitude and an absolute-error fit would ignore everything but the
    largest shape.
    """
    if not probes:
        raise ValueError("fit_roofline needs at least one probe")
    A = np.array([[1.0, p.flops, p.hbm_bytes] for p in probes], np.float64)
    t = np.array([p.seconds for p in probes], np.float64)
    w = 1.0 / np.maximum(t, 1e-12)
    coef = _nnls(A * w[:, None], t * w)
    pred = A @ coef
    rel = float(np.linalg.norm((pred - t) * w)) / np.sqrt(len(probes))
    achieved = max(p.flops / p.seconds for p in probes if p.seconds > 0)
    return RooflineFit(overhead_s=float(coef[0]),
                       inv_compute=float(coef[1]),
                       inv_bandwidth=float(coef[2]),
                       achieved_flops_per_s=float(achieved),
                       rel_residual=rel,
                       n_probes=len(probes),
                       backend=probes[0].backend)


# ---------------------------------------------------------------------------
# Per-layer HBM footprint (the bandwidth coordinate of a model layer)
# ---------------------------------------------------------------------------

_WEIGHT_BYTES = 2   # bf16 resident backbone
_ACT_BYTES = 4      # fp32 probe/compute activations


def layer_hbm_bytes(cfg: ModelConfig, tokens: int) -> float:
    """One decoder layer's forward HBM traffic: stream the (bf16) weights
    once + read/write/residual the activation tensor."""
    return (cfg.params_per_layer() * _WEIGHT_BYTES
            + 3.0 * tokens * cfg.d_model * _ACT_BYTES)


def embed_hbm_bytes(cfg: ModelConfig, tokens: int) -> float:
    """Embedding lookup: gather ``tokens`` rows + write the activations."""
    return (tokens * cfg.d_model * _WEIGHT_BYTES
            + tokens * cfg.d_model * _ACT_BYTES)


def head_hbm_bytes(cfg: ModelConfig, tokens: int) -> float:
    """LM head: stream the (d, V) matrix + write the logits."""
    return (cfg.d_model * cfg.vocab_size * _WEIGHT_BYTES
            + tokens * cfg.vocab_size * _ACT_BYTES)


# ---------------------------------------------------------------------------
# LatencyTable — the measured backend cost_model.py plugs in
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LatencyTable:
    """Per-layer forward latencies for one (arch, batch, seq) workload.

    ``seconds * ref_throughput`` is the effective-FLOPs currency consumed by
    ``TableCompute`` — with ``ref_throughput=1.0`` and seconds equal to the
    analytic FLOP counts, the table reproduces the analytic model exactly
    (the equivalence the tests pin down).
    """
    arch: str
    batch: int
    seq_len: int
    ref_throughput: float        # FLOP/s the seconds are normalized against
    embed_s: float               # forward seconds for the whole mini-batch
    layer_s: Tuple[float, ...]   # per decoder layer, len == cfg.n_layers
    head_s: float
    source: str = "measured"     # "analytic" | "measured:<backend>"

    def __post_init__(self):
        if not (0 < self.ref_throughput < float("inf")):
            raise ValueError("ref_throughput must be positive and finite")
        if any(s < 0 for s in self.layer_s):
            raise ValueError("negative per-layer latency")

    @property
    def n_layers(self) -> int:
        return len(self.layer_s)

    # ---- constructors ------------------------------------------------------
    @classmethod
    def from_analytic(cls, workload: Workload) -> "LatencyTable":
        """Synthesize the table that reproduces the analytic model exactly:
        ref_throughput 1.0, 'seconds' = forward FLOPs of each component."""
        cfg, tok = workload.cfg, workload.tokens
        layer = layer_fwd_flops_per_token(cfg, workload.seq_len) * tok
        return cls(arch=cfg.name, batch=workload.batch,
                   seq_len=workload.seq_len, ref_throughput=1.0,
                   embed_s=embed_fwd_flops_per_token(cfg) * tok,
                   layer_s=(layer,) * cfg.n_layers,
                   head_s=head_fwd_flops_per_token(cfg) * tok,
                   source="analytic")

    @classmethod
    def from_fit(cls, cfg: ModelConfig, fit: RooflineFit, *, batch: int,
                 seq_len: int) -> "LatencyTable":
        """Predict per-layer latency from the fitted roofline: compute term
        (analytic FLOPs / C) + bandwidth term (HBM footprint / B)."""
        tok = batch * seq_len
        layer = fit.predict(layer_fwd_flops_per_token(cfg, seq_len) * tok,
                            layer_hbm_bytes(cfg, tok))
        return cls(arch=cfg.name, batch=batch, seq_len=seq_len,
                   ref_throughput=fit.ref_throughput,
                   embed_s=fit.predict(embed_fwd_flops_per_token(cfg) * tok,
                                       embed_hbm_bytes(cfg, tok)),
                   layer_s=(layer,) * cfg.n_layers,
                   head_s=fit.predict(head_fwd_flops_per_token(cfg) * tok,
                                      head_hbm_bytes(cfg, tok)),
                   source=f"measured:{fit.backend}")

    # ---- serialization (the BENCH_kernels.json payload) --------------------
    def to_dict(self) -> Dict:
        return {"schema": LATENCY_TABLE_SCHEMA, "arch": self.arch,
                "batch": self.batch, "seq_len": self.seq_len,
                "ref_throughput": self.ref_throughput,
                "embed_s": self.embed_s, "layer_s": list(self.layer_s),
                "head_s": self.head_s, "source": self.source}

    @classmethod
    def from_dict(cls, d: Dict) -> "LatencyTable":
        if d.get("schema") != LATENCY_TABLE_SCHEMA:
            raise ValueError(f"not a latency table: {d.get('schema')!r}")
        return cls(arch=d["arch"], batch=d["batch"], seq_len=d["seq_len"],
                   ref_throughput=d["ref_throughput"], embed_s=d["embed_s"],
                   layer_s=tuple(d["layer_s"]), head_s=d["head_s"],
                   source=d.get("source", "measured"))


def build_latency_tables(fit: RooflineFit, *, batch: int, seq_len: int,
                         archs: Sequence[str] = ARCH_IDS
                         ) -> Dict[str, LatencyTable]:
    """One calibrated table per architecture config from a single host fit."""
    return {a: LatencyTable.from_fit(get_config(a), fit, batch=batch,
                                     seq_len=seq_len) for a in archs}


# ---------------------------------------------------------------------------
# TableCompute — cost_model's "measured" ComputeSource implementation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TableCompute:
    """Effective-FLOPs view of a ``LatencyTable`` for one workload.

    The interface ``cost_model.resolve_compute`` expects: ``device_flops``,
    ``server_flops``, ``total_flops`` — drop-in for ``AnalyticCompute``, so
    ``card``/``batched_card`` decide on measured numbers transparently.
    """
    workload: Workload
    table: LatencyTable

    def __post_init__(self):
        cfg = self.workload.cfg
        if self.table.arch != cfg.name:
            raise ValueError(f"latency table is for {self.table.arch!r}, "
                             f"workload is {cfg.name!r}")
        if self.table.n_layers != cfg.n_layers:
            raise ValueError(f"table has {self.table.n_layers} layers, "
                             f"config has {cfg.n_layers}")
        if (self.table.batch, self.table.seq_len) != (self.workload.batch,
                                                      self.workload.seq_len):
            raise ValueError(
                f"table measured at (batch={self.table.batch}, "
                f"seq={self.table.seq_len}) but workload is "
                f"(batch={self.workload.batch}, seq={self.workload.seq_len})")

    @cached_property
    def _cum_layer_s(self) -> np.ndarray:
        # cum[c] = forward seconds of layers [0, c); cum[0] = 0
        return np.concatenate([[0.0], np.cumsum(np.asarray(self.table.layer_s,
                                                           np.float64))])

    def device_flops(self, cut: int) -> float:
        """Effective eta_D(c): embedding + layers [0, cut), fwd+bwd."""
        t = self.table
        return (LORA_TRAIN_FACTOR * (t.embed_s + self._cum_layer_s[cut])
                * t.ref_throughput)

    def total_flops(self) -> float:
        t = self.table
        return (LORA_TRAIN_FACTOR
                * (t.embed_s + self._cum_layer_s[t.n_layers] + t.head_s)
                * t.ref_throughput)

    def server_flops(self, cut: int) -> float:
        return self.total_flops() - self.device_flops(cut)
