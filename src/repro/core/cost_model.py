"""Analytic delay/energy model — Sec. III of the paper, per architecture.

All quantities are derived from the ``ModelConfig`` so the cost model works
for every assigned architecture, not just the paper's LLaMA-1B:

  eta_D(c)   — FLOPs of the device-side stage at cut layer c (Eq. 7 numerator)
  eta        — FLOPs of the whole fine-tuning step (Eq. 8)
  S(c), S~(c) — smashed data / gradient bytes (Eq. 9); identical across cuts
                for uniform layer stacks (the paper's Fig. 3 observation)
  A(c)       — device-side LoRA adapter bytes (Eq. 9)
  D_{m,n}    — Eq. 10;  E_{m,n} — Eq. 11;  U — Eq. 12.

FLOPs accounting: LoRA fine-tuning needs forward + backward-through-frozen
weights (dX GEMMs) + adapter-gradient GEMMs, i.e. ~2x forward FLOPs + the
(negligible) adapter terms; we count them exactly below. MoE layers count
*active* FLOPs (top-k + shared experts) — this breaks the paper's
"every layer costs the same" symmetry only across families, not within a
uniform stack, so Fig. 3's bimodal-cut finding is preserved per-arch.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.channel import ChannelState
from repro.core.hardware import DeviceProfile, SimParams


# ---------------------------------------------------------------------------
# FLOPs per component (forward, per token)
# ---------------------------------------------------------------------------


def attn_fwd_flops_per_token(cfg: ModelConfig, seq_len: int) -> float:
    if cfg.is_attention_free:
        return 0.0
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    proj = 2 * d * (q + 2 * kv) + 2 * q * d
    # causal scores + weighted sum: 2 * 2 * (S/2) * q_dim
    scores = 2 * seq_len * q  # (2 matmuls x S x q_dim x ... / 2 causal)
    return proj + scores


def mlp_fwd_flops_per_token(cfg: ModelConfig) -> float:
    d = cfg.d_model
    if cfg.is_moe:
        routed = 2 * 3 * d * cfg.d_ff * cfg.top_k
        shared = 2 * 3 * d * cfg.d_ff * cfg.n_shared_experts
        router = 2 * d * cfg.n_experts
        return routed + shared + router
    if cfg.family == "ssm":
        return 0.0
    return 2 * 3 * d * cfg.d_ff


def ssm_fwd_flops_per_token(cfg: ModelConfig) -> float:
    if not cfg.has_ssm:
        return 0.0
    d, di, ns = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    proj = 2 * d * (2 * di + 2 * ns + cfg.ssm_n_heads) + 2 * di * d
    conv = 2 * cfg.ssm_conv_width * (di + 2 * ns)
    # SSD: intra-chunk quadratic (~2*chunk*di) + state update (~4*di*ns)
    ssd = 2 * cfg.ssm_chunk * di + 4 * di * ns
    return proj + conv + ssd


def lora_fwd_flops_per_token(cfg: ModelConfig) -> float:
    return 2 * cfg.lora_params_per_layer()


def layer_fwd_flops_per_token(cfg: ModelConfig, seq_len: int) -> float:
    return (attn_fwd_flops_per_token(cfg, seq_len)
            + mlp_fwd_flops_per_token(cfg)
            + ssm_fwd_flops_per_token(cfg)
            + lora_fwd_flops_per_token(cfg))


def embed_fwd_flops_per_token(cfg: ModelConfig) -> float:
    return 2 * cfg.d_model  # lookup + scale; head counted server-side


def head_fwd_flops_per_token(cfg: ModelConfig) -> float:
    return 2 * cfg.d_model * cfg.vocab_size


# LoRA training ~= 2x forward (dX GEMMs through frozen weights) + adapter
# gradient GEMMs (~= forward cost of the adapters themselves).
LORA_TRAIN_FACTOR = 2.0


@dataclass(frozen=True)
class Workload:
    """One mini-batch fine-tuning step of (batch x seq) tokens."""
    cfg: ModelConfig
    batch: int
    seq_len: int

    @property
    def tokens(self) -> int:
        return self.batch * self.seq_len

    # ---- eta(c): Eq. 7/8 numerators ---------------------------------------
    def device_flops(self, cut: int) -> float:
        """eta_D(c): embedding + layers [0, cut), fwd+bwd, LoRA-frozen."""
        per_tok = (embed_fwd_flops_per_token(self.cfg)
                   + cut * layer_fwd_flops_per_token(self.cfg, self.seq_len))
        return LORA_TRAIN_FACTOR * per_tok * self.tokens

    def total_flops(self) -> float:
        """eta: the whole model (device + server sides), fwd+bwd."""
        cfg = self.cfg
        per_tok = (embed_fwd_flops_per_token(cfg)
                   + cfg.n_layers * layer_fwd_flops_per_token(cfg, self.seq_len)
                   + head_fwd_flops_per_token(cfg))
        return LORA_TRAIN_FACTOR * per_tok * self.tokens

    def server_flops(self, cut: int) -> float:
        return self.total_flops() - self.device_flops(cut)

    # ---- data sizes: Eq. 9 -------------------------------------------------
    def smashed_bytes(self, cut: int, act_bytes: int) -> float:
        """S(c): activations at the cut + labels. Constant across cuts for a
        uniform stack (matches the paper's observation)."""
        acts = self.tokens * self.cfg.d_model * act_bytes
        labels = self.tokens * 4
        return acts + labels

    def gradient_bytes(self, cut: int, act_bytes: int) -> float:
        """S~(c): gradient of the smashed data."""
        return self.tokens * self.cfg.d_model * act_bytes

    def adapter_bytes(self, cut: int, adapter_bytes: int) -> float:
        """A(c): device-side LoRA adapters for layers [0, cut)."""
        return cut * self.cfg.lora_params_per_layer() * adapter_bytes

    def device_weight_bytes(self, cut: int, weight_bytes: int = 2) -> float:
        """Frozen backbone bytes resident on the device at cut c (for the
        memory-feasibility mask; one-time download excluded from Eq. 9)."""
        per_layer = self.cfg.params_per_layer() * weight_bytes
        embed = self.cfg.vocab_size * self.cfg.d_model * weight_bytes
        return embed + cut * per_layer


# ---------------------------------------------------------------------------
# Delay & energy (Eqs. 7-11)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RoundContext:
    """Everything CARD needs for one (device, round) decision."""
    workload: Workload
    device: DeviceProfile
    server: DeviceProfile
    channel: ChannelState
    sim: SimParams

    # -- Eq. 7: device computation delay per local epoch
    def device_comp_delay(self, cut: int) -> float:
        return self.workload.device_flops(cut) / self.device.peak_flops

    # -- Eq. 8: server computation delay per local epoch at frequency f
    def server_comp_delay(self, cut: int, f: float) -> float:
        return self.workload.server_flops(cut) / self.server.throughput(f)

    # -- Eq. 9: total transmission delay for a round (bits / (bit/s))
    def transmission_delay(self, cut: int) -> float:
        w, sim, ch = self.workload, self.sim, self.channel
        t = sim.local_epochs
        up = 8 * sim.phi * w.smashed_bytes(cut, sim.act_bytes) / ch.rate_up
        down = 8 * sim.phi * w.gradient_bytes(cut, sim.act_bytes) / ch.rate_down
        adapters = (8 * w.adapter_bytes(cut, sim.adapter_bytes)
                    * (1.0 / ch.rate_up + 1.0 / ch.rate_down))
        return t * (up + down) + adapters

    # -- Eq. 10: total round delay
    def round_delay(self, cut: int, f: float) -> float:
        t = self.sim.local_epochs
        comp = t * (self.device_comp_delay(cut)
                    + self.server_comp_delay(cut, f))
        return comp + self.transmission_delay(cut)

    # -- Eq. 11: server computational energy for the round
    def server_energy(self, cut: int, f: float) -> float:
        t = self.sim.local_epochs
        return (t * self.sim.xi * f ** 2 * self.workload.server_flops(cut)
                / (self.server.delta * self.server.sigma))

    # -- feasibility: frozen device-side weights must fit device RAM
    def max_feasible_cut(self) -> int:
        cfg = self.workload.cfg
        budget = 0.8 * self.device.mem_bytes
        for c in range(cfg.n_layers, -1, -1):
            if self.workload.device_weight_bytes(c) <= budget:
                return c
        return 0

    # -- normalization corners (Sec. III-C):
    #    D_max, E_min at (c=I, f=F_min);  D_min, E_max at (c=0, f=F_max)
    def corners(self) -> Tuple[float, float, float, float]:
        cfg = self.workload.cfg
        f_min = self.f_min()
        f_max = self.server.f_max
        d_max = self.round_delay(cfg.n_layers, f_min)
        e_min = self.server_energy(cfg.n_layers, f_min)   # = 0
        d_min = self.round_delay(0, f_max)
        e_max = self.server_energy(0, f_max)
        return d_min, d_max, e_min, e_max

    def f_min(self) -> float:
        """F_min^{m,S} = f_m delta_m sigma_m / (delta_S sigma_S): the server
        must be at least as fast as the device (Sec. III-C)."""
        lower = (self.device.peak_flops
                 / (self.server.delta * self.server.sigma))
        return max(lower, self.server.f_min)

    # -- Eq. 12: scalarized cost
    def cost(self, cut: int, f: float,
             corners: Optional[Tuple[float, float, float, float]] = None
             ) -> float:
        if corners is None:
            corners = self.corners()
        d_min, d_max, e_min, e_max = corners
        w = self.sim.w
        d = self.round_delay(cut, f)
        e = self.server_energy(cut, f)
        dn = (d - d_min) / max(d_max - d_min, 1e-12)
        en = (e - e_min) / max(e_max - e_min, 1e-12)
        return w * dn + (1 - w) * en
