"""Analytic delay/energy model — Sec. III of the paper, per architecture.

All quantities are derived from the ``ModelConfig`` so the cost model works
for every assigned architecture, not just the paper's LLaMA-1B:

  eta_D(c)   — FLOPs of the device-side stage at cut layer c (Eq. 7 numerator)
  eta        — FLOPs of the whole fine-tuning step (Eq. 8)
  S(c), S~(c) — smashed data / gradient bytes (Eq. 9); identical across cuts
                for uniform layer stacks (the paper's Fig. 3 observation)
  A(c)       — device-side LoRA adapter bytes (Eq. 9)
  D_{m,n}    — Eq. 10;  E_{m,n} — Eq. 11;  U — Eq. 12.

FLOPs accounting: LoRA fine-tuning needs forward + backward-through-frozen
weights (dX GEMMs) + adapter-gradient GEMMs, i.e. ~2x forward FLOPs + the
(negligible) adapter terms; we count them exactly below. MoE layers count
*active* FLOPs (top-k + shared experts) — this breaks the paper's
"every layer costs the same" symmetry only across families, not within a
uniform stack, so Fig. 3's bimodal-cut finding is preserved per-arch.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.channel import ChannelBatch, ChannelState
from repro.core.hardware import (DeviceProfile, ServerTier, SimParams,
                                 fleet_arrays, tier_arrays)


# ---------------------------------------------------------------------------
# FLOPs per component (forward, per token)
# ---------------------------------------------------------------------------


def attn_fwd_flops_per_token(cfg: ModelConfig, seq_len: int) -> float:
    """Forward FLOPs per token of one attention block (QKV/out projections
    plus causal scores at ``seq_len``); 0.0 for attention-free archs."""
    if cfg.is_attention_free:
        return 0.0
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    proj = 2 * d * (q + 2 * kv) + 2 * q * d
    # causal scores + weighted sum: 2 * 2 * (S/2) * q_dim
    scores = 2 * seq_len * q  # (2 matmuls x S x q_dim x ... / 2 causal)
    return proj + scores


def mlp_fwd_flops_per_token(cfg: ModelConfig) -> float:
    """Forward FLOPs per token of one MLP block — gated 3-matmul for dense,
    routed top-k + shared experts + router for MoE, 0.0 for pure SSM."""
    d = cfg.d_model
    if cfg.is_moe:
        routed = 2 * 3 * d * cfg.d_ff * cfg.top_k
        shared = 2 * 3 * d * cfg.d_ff * cfg.n_shared_experts
        router = 2 * d * cfg.n_experts
        return routed + shared + router
    if cfg.family == "ssm":
        return 0.0
    return 2 * 3 * d * cfg.d_ff


def ssm_fwd_flops_per_token(cfg: ModelConfig) -> float:
    """Forward FLOPs per token of one SSM (Mamba-2) block: in/out
    projections, short conv, and the SSD chunked scan; 0.0 without SSM."""
    if not cfg.has_ssm:
        return 0.0
    d, di, ns = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    proj = 2 * d * (2 * di + 2 * ns + cfg.ssm_n_heads) + 2 * di * d
    conv = 2 * cfg.ssm_conv_width * (di + 2 * ns)
    # SSD: intra-chunk quadratic (~2*chunk*di) + state update (~4*di*ns)
    ssd = 2 * cfg.ssm_chunk * di + 4 * di * ns
    return proj + conv + ssd


def lora_fwd_flops_per_token(cfg: ModelConfig) -> float:
    return 2 * cfg.lora_params_per_layer()


def layer_fwd_flops_per_token(cfg: ModelConfig, seq_len: int) -> float:
    return (attn_fwd_flops_per_token(cfg, seq_len)
            + mlp_fwd_flops_per_token(cfg)
            + ssm_fwd_flops_per_token(cfg)
            + lora_fwd_flops_per_token(cfg))


def embed_fwd_flops_per_token(cfg: ModelConfig) -> float:
    return 2 * cfg.d_model  # lookup + scale; head counted server-side


def head_fwd_flops_per_token(cfg: ModelConfig) -> float:
    return 2 * cfg.d_model * cfg.vocab_size


# LoRA training ~= 2x forward (dX GEMMs through frozen weights) + adapter
# gradient GEMMs (~= forward cost of the adapters themselves).
LORA_TRAIN_FACTOR = 2.0

# Fraction of device RAM the frozen backbone may occupy (the rest is
# activations/runtime). Shared by the scalar and batched feasibility masks.
MEM_BUDGET_FRACTION = 0.8


@dataclass(frozen=True)
class Workload:
    """One mini-batch fine-tuning step of (batch x seq) tokens."""
    cfg: ModelConfig
    batch: int
    seq_len: int

    @property
    def tokens(self) -> int:
        return self.batch * self.seq_len

    # ---- eta(c): Eq. 7/8 numerators ---------------------------------------
    def device_flops(self, cut: int) -> float:
        """eta_D(c): embedding + layers [0, cut), fwd+bwd, LoRA-frozen."""
        per_tok = (embed_fwd_flops_per_token(self.cfg)
                   + cut * layer_fwd_flops_per_token(self.cfg, self.seq_len))
        return LORA_TRAIN_FACTOR * per_tok * self.tokens

    def total_flops(self) -> float:
        """eta: the whole model (device + server sides), fwd+bwd."""
        cfg = self.cfg
        per_tok = (embed_fwd_flops_per_token(cfg)
                   + cfg.n_layers * layer_fwd_flops_per_token(cfg, self.seq_len)
                   + head_fwd_flops_per_token(cfg))
        return LORA_TRAIN_FACTOR * per_tok * self.tokens

    def server_flops(self, cut: int) -> float:
        return self.total_flops() - self.device_flops(cut)

    # ---- data sizes: Eq. 9 -------------------------------------------------
    def smashed_bytes(self, cut: int, act_bytes: int) -> float:
        """S(c): activations at the cut + labels. Constant across cuts for a
        uniform stack (matches the paper's observation)."""
        acts = self.tokens * self.cfg.d_model * act_bytes
        labels = self.tokens * 4
        return acts + labels

    def gradient_bytes(self, cut: int, act_bytes: int) -> float:
        """S~(c): gradient of the smashed data."""
        return self.tokens * self.cfg.d_model * act_bytes

    def adapter_bytes(self, cut: int, adapter_bytes: int) -> float:
        """A(c): device-side LoRA adapters for layers [0, cut)."""
        return cut * self.cfg.lora_params_per_layer() * adapter_bytes

    def device_weight_bytes(self, cut: int, weight_bytes: int = 2) -> float:
        """Frozen backbone bytes resident on the device at cut c (for the
        memory-feasibility mask; one-time download excluded from Eq. 9)."""
        per_layer = self.cfg.params_per_layer() * weight_bytes
        embed = self.cfg.vocab_size * self.cfg.d_model * weight_bytes
        return embed + cut * per_layer


# ---------------------------------------------------------------------------
# Pluggable per-layer compute interface
# ---------------------------------------------------------------------------
#
# Every per-cut compute quantity CARD consumes is routed through a
# ``ComputeSource``: three methods returning *effective FLOPs at peak*
# (device side, server side, total).  The analytic FLOPs/frequency path is
# one implementation; ``measured_cost.TableCompute`` — effective FLOPs
# back-converted from a calibrated per-layer latency table — is the other.
# Delay algebra, Eq. 16's closed form, and both CARD engines are agnostic
# to which one is plugged in.


@dataclass(frozen=True)
class AnalyticCompute:
    """The paper's analytic FLOP counts (Sec. III), as a ComputeSource."""
    workload: Workload

    def device_flops(self, cut: int) -> float:
        return self.workload.device_flops(cut)

    def server_flops(self, cut: int) -> float:
        return self.workload.server_flops(cut)

    def total_flops(self) -> float:
        return self.workload.total_flops()


COST_SOURCES = ("analytic", "measured")


def resolve_compute(workload: Workload, cost_source: str = "analytic",
                    latency_table=None):
    """Pick the ComputeSource for ``cost_source``.

    ``"analytic"`` — FLOP counts from the ``Workload`` (paper constants).
    ``"measured"`` — effective FLOPs from a ``measured_cost.LatencyTable``
    calibrated against kernel timings (must be passed as ``latency_table``).
    """
    if cost_source == "analytic":
        return AnalyticCompute(workload)
    if cost_source == "measured":
        if latency_table is None:
            raise ValueError("cost_source='measured' requires a latency_table"
                             " (see repro.core.measured_cost.LatencyTable)")
        from repro.core.measured_cost import TableCompute
        return TableCompute(workload=workload, table=latency_table)
    raise ValueError(f"unknown cost_source {cost_source!r}; "
                     f"expected one of {COST_SOURCES}")


# ---------------------------------------------------------------------------
# Delay & energy (Eqs. 7-11)
# ---------------------------------------------------------------------------


class DelayBreakdown(NamedTuple):
    """Per-component round delay: Eq. 10 split into its four terms.

    Needed for exact parallel-SL round times (Wu et al. JSAC'23 extension):
    in parallel SL only the server-compute term contends across devices, so
    the breakdown — not the scalar total — is what the scheduler must know.
    """
    device_comp: float   # t * device-side compute (Eq. 7 term)
    uplink: float        # smashed data up + adapter upload (Eq. 9)
    server_comp: float   # t * server-side compute (Eq. 8 term)
    downlink: float      # gradients down + adapter download (Eq. 9)

    @property
    def total(self):
        return self.device_comp + self.uplink + self.server_comp + self.downlink


@dataclass(frozen=True)
class RoundContext:
    """Everything CARD needs for one (device, round) decision.

    ``cost_source`` selects the per-layer compute backend: ``"analytic"``
    (paper FLOP counts, the default) or ``"measured"`` (a kernel-calibrated
    ``measured_cost.LatencyTable`` passed as ``latency_table``).
    """
    workload: Workload
    device: DeviceProfile
    server: DeviceProfile
    channel: ChannelState
    sim: SimParams
    cost_source: str = "analytic"
    latency_table: Optional[object] = None

    @cached_property
    def compute(self):
        return resolve_compute(self.workload, self.cost_source,
                               self.latency_table)

    # -- Eq. 7: device computation delay per local epoch
    def device_comp_delay(self, cut: int) -> float:
        return self.compute.device_flops(cut) / self.device.peak_flops

    # -- Eq. 8: server computation delay per local epoch at frequency f
    def server_comp_delay(self, cut: int, f: float) -> float:
        return self.compute.server_flops(cut) / self.server.throughput(f)

    # -- Eqs. 9-10 split by component; the single source of the delay algebra
    def delay_components(self, cut: int, f: float) -> DelayBreakdown:
        w, sim, ch = self.workload, self.sim, self.channel
        t = sim.local_epochs
        adapters = 8 * w.adapter_bytes(cut, sim.adapter_bytes)
        up = (t * 8 * sim.phi * w.smashed_bytes(cut, sim.act_bytes)
              + adapters) / ch.rate_up
        down = (t * 8 * sim.phi * w.gradient_bytes(cut, sim.act_bytes)
                + adapters) / ch.rate_down
        return DelayBreakdown(device_comp=t * self.device_comp_delay(cut),
                              uplink=up,
                              server_comp=t * self.server_comp_delay(cut, f),
                              downlink=down)

    # -- Eq. 9: total transmission delay for a round (bits / (bit/s))
    def transmission_delay(self, cut: int) -> float:
        parts = self.delay_components(cut, self.server.f_max)
        return parts.uplink + parts.downlink

    # -- Eq. 10: total round delay
    def round_delay(self, cut: int, f: float) -> float:
        return self.delay_components(cut, f).total

    # -- Eq. 11: server computational energy for the round
    def server_energy(self, cut: int, f: float) -> float:
        t = self.sim.local_epochs
        return (t * self.sim.xi * f ** 2 * self.compute.server_flops(cut)
                / (self.server.delta * self.server.sigma))

    # -- feasibility: frozen device-side weights must fit device RAM
    def max_feasible_cut(self) -> int:
        cfg = self.workload.cfg
        budget = MEM_BUDGET_FRACTION * self.device.mem_bytes
        for c in range(cfg.n_layers, -1, -1):
            if self.workload.device_weight_bytes(c) <= budget:
                return c
        return 0

    # -- normalization corners (Sec. III-C):
    #    D_max, E_min at (c=I, f=F_min);  D_min, E_max at (c=0, f=F_max)
    def corners(self) -> Tuple[float, float, float, float]:
        cfg = self.workload.cfg
        f_min = self.f_min()
        f_max = self.server.f_max
        d_max = self.round_delay(cfg.n_layers, f_min)
        e_min = self.server_energy(cfg.n_layers, f_min)   # = 0
        d_min = self.round_delay(0, f_max)
        e_max = self.server_energy(0, f_max)
        return d_min, d_max, e_min, e_max

    def f_min(self) -> float:
        """F_min^{m,S} = f_m delta_m sigma_m / (delta_S sigma_S): the server
        must be at least as fast as the device (Sec. III-C)."""
        lower = (self.device.peak_flops
                 / (self.server.delta * self.server.sigma))
        return max(lower, self.server.f_min)

    # -- Eq. 12: scalarized cost
    def cost(self, cut: int, f: float,
             corners: Optional[Tuple[float, float, float, float]] = None
             ) -> float:
        if corners is None:
            corners = self.corners()
        d_min, d_max, e_min, e_max = corners
        w = self.sim.w
        d = self.round_delay(cut, f)
        e = self.server_energy(cut, f)
        dn = (d - d_min) / max(d_max - d_min, 1e-12)
        en = (e - e_min) / max(e_max - e_min, 1e-12)
        return w * dn + (1 - w) * en


# ---------------------------------------------------------------------------
# Batched fleet context — array-in/array-out Eqs. 7-12
# ---------------------------------------------------------------------------


def _per_cut_tables(workload: Workload, sim: SimParams, compute) -> dict:
    """Float64 per-cut tables shared by the batched and tiered contexts.

    One accounting for both: ``dev_flops``/``srv_flops`` (effective FLOPs,
    Eqs. 7-8), ``up_bits``/``down_bits`` (per-local-epoch phi-compressed
    smashed/gradient bits, Eq. 9), ``adapter_bits`` (once-per-round adapter
    exchange bits), ``weight_bytes`` (frozen device-side backbone bytes for
    the memory-feasibility mask). Every array has shape ``(C,)`` with
    ``C = n_layers + 1`` candidate cuts.
    """
    cuts = range(workload.cfg.n_layers + 1)
    return {
        "dev_flops": np.array([compute.device_flops(c) for c in cuts]),
        "srv_flops": np.array([compute.server_flops(c) for c in cuts]),
        "up_bits": np.array([8 * sim.phi * workload.smashed_bytes(
            c, sim.act_bytes) for c in cuts]),
        "down_bits": np.array([8 * sim.phi * workload.gradient_bytes(
            c, sim.act_bytes) for c in cuts]),
        "adapter_bits": np.array([8 * workload.adapter_bytes(
            c, sim.adapter_bytes) for c in cuts]),
        "weight_bytes": np.array([workload.device_weight_bytes(c)
                                  for c in cuts]),
    }


def _max_cut_per_device(weight_bytes: np.ndarray,
                        mem_bytes: np.ndarray) -> np.ndarray:
    """Largest feasible cut per device: the frozen device-side weights at
    cut c must fit ``MEM_BUDGET_FRACTION`` of device RAM. ``weight_bytes``
    is the per-cut ``(C,)`` table, ``mem_bytes`` the ``(D,)`` fleet array;
    returns int ``(D,)`` (0 when not even the embedding fits)."""
    feas = (weight_bytes[None, :]
            <= MEM_BUDGET_FRACTION * mem_bytes[:, None])       # (D, C)
    return np.where(feas.any(axis=1),
                    feas.shape[1] - 1 - np.argmax(feas[:, ::-1], axis=1),
                    0)


@dataclass(frozen=True)
class BatchedRoundContext:
    """``RoundContext`` for a whole fleet sweep at once.

    Per-cut tables are precomputed in float64 from the scalar ComputeSource
    — analytic ``Workload`` FLOPs or a measured ``LatencyTable``, selected
    by ``build(..., cost_source=...)`` exactly as in ``RoundContext`` (so
    scalar and batched paths share one accounting), then cast to the
    active jnp precision — float32 unless ``jax_enable_x64`` — and the
    delay/energy/cost algebra runs as jnp broadcasting over a ``(rounds,
    devices, cuts)`` tensor. The bimodal cost structure (Fig. 3) keeps the
    argmin far from float32 eps in practice, but a pathologically
    near-tied fleet could pick the other endpoint than the float64 scalar
    oracle. Shape conventions:

      tables       (C,)    — C = n_layers + 1 candidate cuts
      per-device   (D,)
      channel      (R, D)  — one link realization per (round, device)

    ``cuts`` arguments index the tables and may be any shape broadcastable
    against trailing layout ``(R, D, C')`` (typically ``(C,)`` for the full
    grid, or ``(R, D, 1)`` for per-decision evaluation); ``f`` is a scalar
    or an ``(R, D)`` per-decision frequency.
    """
    # per-cut tables (C,)
    dev_flops: jnp.ndarray       # eta_D(c), fwd+bwd FLOPs
    srv_flops: jnp.ndarray       # eta - eta_D(c)
    up_bits: jnp.ndarray         # per-local-epoch phi-compressed smashed bits
    down_bits: jnp.ndarray       # per-local-epoch phi-compressed gradient bits
    adapter_bits: jnp.ndarray    # once-per-round adapter exchange bits
    # per-device (D,)
    peak_flops: jnp.ndarray
    max_cut: jnp.ndarray         # memory-feasibility cap, int32
    # per-(round, device) (R, D)
    rate_up: jnp.ndarray
    rate_down: jnp.ndarray
    # Eq. 12 weights as 0-d arrays (data, not jit-static: a w-sweep like
    # ablation_pareto must reuse one compiled grid across all w values)
    w: jnp.ndarray
    xi: jnp.ndarray
    # static hyperparameters (pytree aux data)
    local_epochs: int
    server_tp_per_hz: float      # delta_S * sigma_S
    server_f_max: float
    server_f_min: float

    @classmethod
    def build(cls, workload: Workload, devices: Sequence[DeviceProfile],
              server: DeviceProfile, channels: ChannelBatch,
              sim: SimParams, *, cost_source: str = "analytic",
              latency_table=None) -> "BatchedRoundContext":
        compute = resolve_compute(workload, cost_source, latency_table)
        tables = _per_cut_tables(workload, sim, compute)
        arrs = fleet_arrays(devices)
        # memory feasibility: largest c whose frozen weights fit the budget
        max_cut = _max_cut_per_device(tables["weight_bytes"],
                                      arrs["mem_bytes"])
        return cls(
            dev_flops=jnp.asarray(tables["dev_flops"]),
            srv_flops=jnp.asarray(tables["srv_flops"]),
            up_bits=jnp.asarray(tables["up_bits"]),
            down_bits=jnp.asarray(tables["down_bits"]),
            adapter_bits=jnp.asarray(tables["adapter_bits"]),
            peak_flops=jnp.asarray(arrs["peak_flops"]),
            max_cut=jnp.asarray(max_cut, jnp.int32),
            rate_up=jnp.asarray(channels.rate_up),
            rate_down=jnp.asarray(channels.rate_down),
            w=jnp.asarray(float(sim.w)), xi=jnp.asarray(float(sim.xi)),
            local_epochs=int(sim.local_epochs),
            server_tp_per_hz=float(server.delta * server.sigma),
            server_f_max=float(server.f_max), server_f_min=float(server.f_min))

    # -- shapes --------------------------------------------------------------
    @property
    def n_cuts(self) -> int:
        return self.dev_flops.shape[0]

    @property
    def shape(self) -> Tuple[int, int]:
        return self.rate_up.shape

    def _f_expand(self, f) -> jnp.ndarray:
        f = jnp.asarray(f)
        return f[..., None] if f.ndim == 2 else f

    # -- Sec. III-C feasible frequency floor, per device ---------------------
    def f_min(self) -> jnp.ndarray:
        return jnp.maximum(self.peak_flops / self.server_tp_per_hz,
                           self.server_f_min)

    # -- Eqs. 7-10, per component -------------------------------------------
    def delay_components(self, cuts, f) -> DelayBreakdown:
        cuts = jnp.asarray(cuts)
        f = self._f_expand(f)
        t = self.local_epochs
        dev = t * self.dev_flops[cuts] / self.peak_flops[:, None]
        srv = t * self.srv_flops[cuts] / (f * self.server_tp_per_hz)
        up = ((t * self.up_bits[cuts] + self.adapter_bits[cuts])
              / self.rate_up[..., None])
        down = ((t * self.down_bits[cuts] + self.adapter_bits[cuts])
                / self.rate_down[..., None])
        dev, up, srv, down = jnp.broadcast_arrays(dev, up, srv, down)
        return DelayBreakdown(device_comp=dev, uplink=up,
                              server_comp=srv, downlink=down)

    def round_delay(self, cuts, f) -> jnp.ndarray:
        return self.delay_components(cuts, f).total

    # -- Eq. 11 --------------------------------------------------------------
    def server_energy(self, cuts, f) -> jnp.ndarray:
        cuts = jnp.asarray(cuts)
        f = self._f_expand(f)
        return (self.local_epochs * self.xi * f ** 2 * self.srv_flops[cuts]
                / self.server_tp_per_hz)

    # -- normalization corners (Sec. III-C), each (R, D) ---------------------
    def corners(self) -> Tuple[jnp.ndarray, jnp.ndarray,
                               jnp.ndarray, jnp.ndarray]:
        last = jnp.array([self.n_cuts - 1])
        first = jnp.array([0])
        f_lo = jnp.broadcast_to(self.f_min(), self.shape)
        f_hi = jnp.full(self.shape, self.server_f_max)
        d_max = self.round_delay(last, f_lo)[..., 0]
        e_min = self.server_energy(last, f_lo)[..., 0]
        d_min = self.round_delay(first, f_hi)[..., 0]
        e_max = self.server_energy(first, f_hi)[..., 0]
        return d_min, d_max, e_min, e_max

    # -- Eq. 12 --------------------------------------------------------------
    def cost(self, cuts, f, corners=None) -> jnp.ndarray:
        if corners is None:
            corners = self.corners()
        d_min, d_max, e_min, e_max = corners
        d = self.round_delay(cuts, f)
        e = self.server_energy(cuts, f)
        dn = ((d - d_min[..., None])
              / jnp.maximum(d_max - d_min, 1e-12)[..., None])
        en = ((e - e_min[..., None])
              / jnp.maximum(e_max - e_min, 1e-12)[..., None])
        return self.w * dn + (1 - self.w) * en


jax.tree_util.register_dataclass(
    BatchedRoundContext,
    data_fields=["dev_flops", "srv_flops", "up_bits", "down_bits",
                 "adapter_bits", "peak_flops", "max_cut", "rate_up",
                 "rate_down", "w", "xi"],
    meta_fields=["local_epochs", "server_tp_per_hz",
                 "server_f_max", "server_f_min"])


# ---------------------------------------------------------------------------
# Tiered fleet context — Eqs. 7-12 with a leading server axis
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TieredRoundContext:
    """``BatchedRoundContext`` for a :class:`~repro.core.hardware.ServerTier`.

    The hierarchical-SL setting (SplitLLM, arXiv:2501.13318): a tier of
    ``S`` edge servers, each with its own DVFS range and backhaul link to
    the aggregator, shared by one fleet of ``D`` devices. Every delay /
    energy / cost tensor gains a leading server axis:

      per-cut tables  (C,)     — device-side quantities, server-agnostic
      per-device      (D,)
      channel         (R, D)   — the device's radio link to its access
                                 point, shared across candidate servers
      per-server      (S,)     — throughput/Hz, DVFS bounds, backhaul

    ``delay_components``/``cost``/``server_energy`` broadcast over
    ``(S, R, D, C')``; ``corners`` is per ``(S, R, D)``. An ``S = 1`` tier
    is numerically identical to the single-server batched context (the
    per-server parameters appear in exactly the same algebraic positions
    — equivalence-tested in ``tests/test_hierarchy.py``).

    Lanes of devices *not* assigned to a server are masked to NaN by
    :meth:`mask_unassigned` — downstream reductions must be NaN-aware,
    exactly like the churn layer's survivor masking.

    Units follow the repo suffix registry: ``*_s`` seconds, ``*_bits``
    bits, ``*_flops`` effective FLOPs, frequencies in Hz, energies in J.
    """
    # per-cut tables (C,)
    dev_flops: jnp.ndarray
    srv_flops: jnp.ndarray
    up_bits: jnp.ndarray
    down_bits: jnp.ndarray
    adapter_bits: jnp.ndarray
    # per-device (D,)
    peak_flops: jnp.ndarray
    max_cut: jnp.ndarray
    # per-(round, device) (R, D)
    rate_up: jnp.ndarray
    rate_down: jnp.ndarray
    # per-server (S,)
    server_tp_per_hz: jnp.ndarray   # delta_S * sigma_S
    server_f_max: jnp.ndarray       # Hz
    server_f_min: jnp.ndarray       # Hz
    backhaul_bits_per_s: jnp.ndarray
    # Eq. 12 weights as 0-d arrays (data, not jit-static)
    w: jnp.ndarray
    xi: jnp.ndarray
    # static hyperparameters (pytree aux data)
    local_epochs: int
    capacity: Tuple[int, ...]       # per-server device cap (host-side input
                                    # to the assignment stage, not traced)

    @classmethod
    def build(cls, workload: Workload, devices: Sequence[DeviceProfile],
              tier: ServerTier, channels: ChannelBatch, sim: SimParams, *,
              cost_source: str = "analytic",
              latency_table=None) -> "TieredRoundContext":
        """Precompute the per-cut tables (same accounting as
        ``BatchedRoundContext.build``) and stack the tier's per-server
        scalars into ``(S,)`` arrays."""
        compute = resolve_compute(workload, cost_source, latency_table)
        tables = _per_cut_tables(workload, sim, compute)
        arrs = fleet_arrays(devices)
        srv = tier_arrays(tier)
        max_cut = _max_cut_per_device(tables["weight_bytes"],
                                      arrs["mem_bytes"])
        return cls(
            dev_flops=jnp.asarray(tables["dev_flops"]),
            srv_flops=jnp.asarray(tables["srv_flops"]),
            up_bits=jnp.asarray(tables["up_bits"]),
            down_bits=jnp.asarray(tables["down_bits"]),
            adapter_bits=jnp.asarray(tables["adapter_bits"]),
            peak_flops=jnp.asarray(arrs["peak_flops"]),
            max_cut=jnp.asarray(max_cut, jnp.int32),
            rate_up=jnp.asarray(channels.rate_up),
            rate_down=jnp.asarray(channels.rate_down),
            server_tp_per_hz=jnp.asarray(srv["tp_per_hz"]),
            server_f_max=jnp.asarray(srv["f_max"]),
            server_f_min=jnp.asarray(srv["f_min"]),
            backhaul_bits_per_s=jnp.asarray(srv["backhaul_bits_per_s"]),
            w=jnp.asarray(float(sim.w)), xi=jnp.asarray(float(sim.xi)),
            local_epochs=int(sim.local_epochs),
            capacity=tuple(int(c) for c in tier.capacity))

    # -- shapes --------------------------------------------------------------
    @property
    def n_cuts(self) -> int:
        return self.dev_flops.shape[0]

    @property
    def n_servers(self) -> int:
        return self.server_tp_per_hz.shape[0]

    @property
    def shape(self) -> Tuple[int, int, int]:
        """(S, R, D) — the per-(server, round, device) decision lattice."""
        return (self.n_servers,) + self.rate_up.shape

    def _f_expand(self, f) -> jnp.ndarray:
        f = jnp.asarray(f)
        return f[..., None] if f.ndim == 3 else f

    # -- Sec. III-C feasible frequency floor, per (server, device) -----------
    def f_min(self) -> jnp.ndarray:
        """(S, D): the server must be at least as fast as the device, per
        candidate server."""
        return jnp.maximum(
            self.peak_flops[None, :] / self.server_tp_per_hz[:, None],
            self.server_f_min[:, None])

    # -- Eqs. 7-10, per component, broadcast over (S, R, D, C') --------------
    def delay_components(self, cuts, f) -> DelayBreakdown:
        """``cuts`` broadcastable against trailing ``(S, R, D, C')``
        (typically the ``(C,)`` grid or an ``(S, R, D, 1)`` decision);
        ``f`` is an ``(S, R, D)`` per-decision server frequency in Hz."""
        cuts = jnp.asarray(cuts)
        f = self._f_expand(f)
        t = self.local_epochs
        dev = (t * self.dev_flops[cuts]
               / self.peak_flops[None, None, :, None])
        srv = (t * self.srv_flops[cuts]
               / (f * self.server_tp_per_hz[:, None, None, None]))
        up = ((t * self.up_bits[cuts] + self.adapter_bits[cuts])
              / self.rate_up[None, ..., None])
        down = ((t * self.down_bits[cuts] + self.adapter_bits[cuts])
                / self.rate_down[None, ..., None])
        dev, up, srv, down = jnp.broadcast_arrays(dev, up, srv, down)
        return DelayBreakdown(device_comp=dev, uplink=up,
                              server_comp=srv, downlink=down)

    def round_delay(self, cuts, f) -> jnp.ndarray:
        return self.delay_components(cuts, f).total

    # -- Eq. 11 --------------------------------------------------------------
    def server_energy(self, cuts, f) -> jnp.ndarray:
        cuts = jnp.asarray(cuts)
        f = self._f_expand(f)
        return (self.local_epochs * self.xi * f ** 2 * self.srv_flops[cuts]
                / self.server_tp_per_hz[:, None, None, None])

    # -- normalization corners (Sec. III-C), each (S, R, D) ------------------
    def corners(self) -> Tuple[jnp.ndarray, jnp.ndarray,
                               jnp.ndarray, jnp.ndarray]:
        last = jnp.array([self.n_cuts - 1])
        first = jnp.array([0])
        f_lo = jnp.broadcast_to(self.f_min()[:, None, :], self.shape)
        f_hi = jnp.broadcast_to(self.server_f_max[:, None, None], self.shape)
        d_max = self.round_delay(last, f_lo)[..., 0]
        e_min = self.server_energy(last, f_lo)[..., 0]
        d_min = self.round_delay(first, f_hi)[..., 0]
        e_max = self.server_energy(first, f_hi)[..., 0]
        return d_min, d_max, e_min, e_max

    # -- Eq. 12 --------------------------------------------------------------
    def cost(self, cuts, f, corners=None) -> jnp.ndarray:
        if corners is None:
            corners = self.corners()
        d_min, d_max, e_min, e_max = corners
        d = self.round_delay(cuts, f)
        e = self.server_energy(cuts, f)
        dn = ((d - d_min[..., None])
              / jnp.maximum(d_max - d_min, 1e-12)[..., None])
        en = ((e - e_min[..., None])
              / jnp.maximum(e_max - e_min, 1e-12)[..., None])
        return self.w * dn + (1 - self.w) * en

    # -- assignment lanes ----------------------------------------------------
    def mask_unassigned(self, x: jnp.ndarray,
                        assign_mask: jnp.ndarray) -> jnp.ndarray:
        """NaN out lanes of (server, device) pairs that are not assigned.

        ``assign_mask`` is bool ``(S, D)``; ``x`` is ``(S, R, D)`` or
        ``(S, R, D, C)``. Mirrors the churn layer's survivor masking: all
        downstream reductions must be NaN-aware.
        """
        m = assign_mask[:, None, :]
        if x.ndim == 4:
            m = m[..., None]
        return jnp.where(m, x, jnp.nan)

    def aggregation_delay(self, assign_mask: jnp.ndarray,
                          cuts: jnp.ndarray) -> jnp.ndarray:
        """Per-(server, round) backhaul aggregation delay in seconds.

        After closing a round, server ``s`` relays the LoRA adapter update
        of each of its assigned devices to the aggregator over its
        backhaul link: ``sum_d adapter_bits[cut_{r,d}] / backhaul``.
        ``assign_mask`` is bool ``(S, D)``, ``cuts`` the int ``(R, D)``
        decision; returns ``(S, R)`` (0 for servers with no devices).
        """
        bits = self.adapter_bits[jnp.asarray(cuts)]             # (R, D)
        per_server_bits = jnp.where(assign_mask[:, None, :],
                                    bits[None, :, :], 0.0).sum(axis=-1)
        return per_server_bits / self.backhaul_bits_per_s[:, None]


jax.tree_util.register_dataclass(
    TieredRoundContext,
    data_fields=["dev_flops", "srv_flops", "up_bits", "down_bits",
                 "adapter_bits", "peak_flops", "max_cut", "rate_up",
                 "rate_down", "server_tp_per_hz", "server_f_max",
                 "server_f_min", "backhaul_bits_per_s", "w", "xi"],
    meta_fields=["local_epochs", "capacity"])
