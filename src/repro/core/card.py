"""CARD — Cut lAyer and computing Resource Decision (Alg. 1, Sec. IV).

  P2 -> (upper layer) closed-form server frequency, Eq. (16):
      f* = clip(Q, F_min^{m,S}, F_max^S),
      Q  = cbrt( w (E_max - E_min) / (2 xi (1-w) (D_max - D_min)) )
  P2 -> (lower layer) brute-force over c in {0..I}: O(I).

Baselines from Sec. V-B:
  server-only — c = 0 (device runs only the embedding module);
  device-only — c = I (device runs embedding + all decoders);
plus static-cut and random-cut baselines for wider comparison.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import BatchedRoundContext, RoundContext


@dataclass(frozen=True)
class Decision:
    cut: int
    frequency: float
    cost: float
    delay: float
    energy: float


class DeadlineSpec(NamedTuple):
    """Straggler-aware deadline objective (churn extension, cf. Efficient
    Split Federated Learning, arXiv:2504.14667).

    The server closes rounds at a deadline; a decision whose nominal delay
    already exceeds it misses with probability 1, one that only misses when
    the device straggles (delay * mean slowdown > deadline) misses with the
    straggler probability, and every decision additionally risks the
    device's dropout. CARD's scalarized cost (Eq. 12) gains
    ``penalty * P(miss)`` so the (cut, f) choice trades delay/energy against
    the round actually committing. All fields are plain floats (jit data).
    """
    deadline_s: float
    p_dropout: float = 0.0
    p_straggler: float = 0.0
    slowdown: float = 1.0      # E[slowdown | straggler event]
    penalty: float = 1.0


def miss_probability(delay, spec: DeadlineSpec):
    """P(decision with nominal ``delay`` misses the round deadline) — jnp
    broadcasting over arbitrarily shaped delay tensors."""
    p_late = jnp.where(delay > spec.deadline_s, 1.0,
                       jnp.where(delay * spec.slowdown > spec.deadline_s,
                                 spec.p_straggler, 0.0))
    return spec.p_dropout + (1.0 - spec.p_dropout) * p_late


def _miss_probability_scalar(delay: float, spec: DeadlineSpec) -> float:
    """Float64 twin of :func:`miss_probability` for the scalar oracle."""
    if delay > spec.deadline_s:
        p_late = 1.0
    elif delay * spec.slowdown > spec.deadline_s:
        p_late = spec.p_straggler
    else:
        p_late = 0.0
    return spec.p_dropout + (1.0 - spec.p_dropout) * p_late


def optimal_frequency(ctx: RoundContext) -> float:
    """Eq. (16). Note Q is independent of c — the frequency subproblem and
    the cut subproblem decouple exactly as the paper exploits."""
    d_min, d_max, e_min, e_max = ctx.corners()
    w, xi = ctx.sim.w, ctx.sim.xi
    if w >= 1.0:
        return ctx.server.f_max
    q = ((w * (e_max - e_min))
         / (2.0 * xi * (1.0 - w) * max(d_max - d_min, 1e-12))) ** (1.0 / 3.0)
    return float(np.clip(q, ctx.f_min(), ctx.server.f_max))


def _evaluate(ctx: RoundContext, cut: int, f: float, corners,
              deadline: Optional[DeadlineSpec] = None) -> Decision:
    delay = ctx.round_delay(cut, f)
    cost = ctx.cost(cut, f, corners)
    if deadline is not None:
        cost += deadline.penalty * _miss_probability_scalar(delay, deadline)
    return Decision(cut=cut, frequency=f, cost=cost, delay=delay,
                    energy=ctx.server_energy(cut, f))


def card(ctx: RoundContext, *, respect_memory: bool = True,
         deadline: Optional[DeadlineSpec] = None) -> Decision:
    """Alg. 1: f* once (line 1), then brute-force c (lines 3-9).

    With ``deadline``, each candidate's cost is penalized by its round-miss
    probability (straggler-aware deadline objective), and every cut gains a
    *rescue* frequency candidate — the server flat out at ``f_max``, the
    delay-minimal point Eq. (16) cannot reach because its closed form does
    not see the deadline. The rescue wins only when its penalized cost is
    strictly lower, so a slack deadline reproduces nominal CARD exactly."""
    corners = ctx.corners()
    f_star = optimal_frequency(ctx)
    max_cut = (ctx.max_feasible_cut() if respect_memory
               else ctx.workload.cfg.n_layers)
    best: Optional[Decision] = None
    for c in range(0, max_cut + 1):
        cand = _evaluate(ctx, c, f_star, corners, deadline)
        if deadline is not None:
            rescue = _evaluate(ctx, c, ctx.server.f_max, corners, deadline)
            if rescue.cost < cand.cost:
                cand = rescue
        if best is None or cand.cost < best.cost:
            best = cand
    assert best is not None
    return best


def card_joint_bruteforce(ctx: RoundContext, *, n_freq: int = 200,
                          respect_memory: bool = True) -> Decision:
    """Exhaustive (f, c) grid — the optimality oracle for tests."""
    corners = ctx.corners()
    freqs = np.linspace(ctx.f_min(), ctx.server.f_max, n_freq)
    max_cut = (ctx.max_feasible_cut() if respect_memory
               else ctx.workload.cfg.n_layers)
    best: Optional[Decision] = None
    for c in range(0, max_cut + 1):
        for f in freqs:
            cand = _evaluate(ctx, c, float(f), corners)
            if best is None or cand.cost < best.cost:
                best = cand
    assert best is not None
    return best


# --- Benchmarks (Sec. V-B) ---------------------------------------------------


def server_only(ctx: RoundContext) -> Decision:
    """Devices fine-tune the embedding module only; server does the rest.
    Server runs flat out (no energy-aware DVFS) — the energy-hungry baseline."""
    return _evaluate(ctx, 0, ctx.server.f_max, ctx.corners())


def device_only(ctx: RoundContext) -> Decision:
    """Devices fine-tune embedding + all transformer decoders locally."""
    # device-only ignores the memory mask: that is precisely its weakness
    cut = ctx.workload.cfg.n_layers
    return _evaluate(ctx, cut, ctx.f_min(), ctx.corners())


def static_cut(ctx: RoundContext, cut: int) -> Decision:
    """Fixed split (the 'static strategies' the paper argues against)."""
    f_star = optimal_frequency(ctx)
    return _evaluate(ctx, cut, f_star, ctx.corners())


def random_cut(ctx: RoundContext, rng: np.random.Generator) -> Decision:
    cut = int(rng.integers(0, ctx.workload.cfg.n_layers + 1))
    return static_cut(ctx, cut)


# ---------------------------------------------------------------------------
# Batched CARD — the whole (rounds x devices x cuts) grid under jit
# ---------------------------------------------------------------------------


class BatchedDecision(NamedTuple):
    """Per-(round, device) decisions; every field is an (R, D) array."""
    cuts: jnp.ndarray         # int32
    freqs: jnp.ndarray        # Hz
    costs: jnp.ndarray        # Eq. 12 scalarized cost
    delays: jnp.ndarray       # Eq. 10 total round delay, s
    energies: jnp.ndarray     # Eq. 11 server energy, J
    d_device: jnp.ndarray     # delay breakdown: device compute
    d_uplink: jnp.ndarray     #                  uplink (smashed + adapters)
    d_server: jnp.ndarray     #                  server compute
    d_downlink: jnp.ndarray   #                  downlink (grads + adapters)


def batched_optimal_frequency(bctx: BatchedRoundContext,
                              corners=None) -> jnp.ndarray:
    """Eq. (16) per (round, device): Q depends only on the corners, which
    depend on the channel draw — hence an (R, D) array of f*."""
    if corners is None:
        corners = bctx.corners()
    d_min, d_max, e_min, e_max = corners
    # w is traced (see BatchedRoundContext): guard the 1-w division and
    # select the pure-delay w=1 endpoint with where, not Python control flow
    q = ((bctx.w * (e_max - e_min))
         / (2.0 * bctx.xi * jnp.maximum(1.0 - bctx.w, 1e-12)
            * jnp.maximum(d_max - d_min, 1e-12))) ** (1.0 / 3.0)
    f = jnp.clip(q, bctx.f_min()[None, :], bctx.server_f_max)
    return jnp.where(bctx.w >= 1.0, bctx.server_f_max, f)


def _batched_evaluate(bctx: BatchedRoundContext, cuts: jnp.ndarray,
                      f: jnp.ndarray, corners,
                      deadline: Optional[DeadlineSpec] = None
                      ) -> BatchedDecision:
    """Metrics for fixed per-(round, device) decisions (cuts, f): (R, D)."""
    c = cuts[..., None]
    parts = bctx.delay_components(c, f)
    delays = parts.total[..., 0]
    costs = bctx.cost(c, f, corners)[..., 0]
    if deadline is not None:
        costs = costs + deadline.penalty * miss_probability(delays, deadline)
    return BatchedDecision(
        cuts=cuts.astype(jnp.int32),
        freqs=jnp.broadcast_to(f, bctx.shape),
        costs=costs,
        delays=delays,
        energies=bctx.server_energy(c, f)[..., 0],
        d_device=parts.device_comp[..., 0], d_uplink=parts.uplink[..., 0],
        d_server=parts.server_comp[..., 0], d_downlink=parts.downlink[..., 0])


@partial(jax.jit, static_argnames=("respect_memory",))
def batched_card(bctx: BatchedRoundContext, *,
                 respect_memory: bool = True,
                 deadline: Optional[DeadlineSpec] = None) -> BatchedDecision:
    """Alg. 1 for the whole fleet: closed-form f* per (round, device), then
    the brute-force over cuts becomes one argmin over the cost tensor.
    ``deadline`` adds the straggler-aware miss-probability penalty and the
    per-cut f_max rescue candidate (same objective as the scalar path)."""
    corners = bctx.corners()
    f_star = batched_optimal_frequency(bctx, corners)
    grid = jnp.arange(bctx.n_cuts)
    freqs = f_star
    cost = bctx.cost(grid, f_star, corners)                 # (R, D, C)
    # structural None checks below: recompile only when the deadline
    # objective is toggled on/off, never per value
    # splint: ignore[trace-safety]
    if deadline is not None:
        def penalized(f):
            base = bctx.cost(grid, f, corners)              # (R, D, C)
            return base + deadline.penalty * miss_probability(
                bctx.round_delay(grid, f), deadline)

        cost = penalized(f_star)
        rescue_cost = penalized(jnp.full(bctx.shape, bctx.server_f_max))
        use_rescue = rescue_cost < cost                     # strict, like
        cost = jnp.where(use_rescue, rescue_cost, cost)     # the scalar path
    if respect_memory:
        infeasible = grid[None, None, :] > bctx.max_cut[None, :, None]
        cost = jnp.where(infeasible, jnp.inf, cost)
    best = jnp.argmin(cost, axis=-1).astype(jnp.int32)      # (R, D)
    # splint: ignore[trace-safety]
    if deadline is not None:
        picked = jnp.take_along_axis(use_rescue, best[..., None],
                                     axis=-1)[..., 0]
        freqs = jnp.where(picked, bctx.server_f_max, f_star)
    return _batched_evaluate(bctx, best, freqs, corners, deadline)


@partial(jax.jit, static_argnames=("n_freq", "respect_memory"))
def batched_card_joint_bruteforce(bctx: BatchedRoundContext, *,
                                  n_freq: int = 200,
                                  respect_memory: bool = True
                                  ) -> BatchedDecision:
    """Exhaustive (f, c) grid, vmapped over the frequency axis — the
    optimality oracle for the batched path. O(F * R * D * C) memory: use
    small fleets (tests), not production sweeps."""
    corners = bctx.corners()
    grid = jnp.arange(bctx.n_cuts)
    fgrid = jnp.linspace(bctx.f_min(), bctx.server_f_max, n_freq)  # (F, D)

    def cost_at(fk):
        cost = bctx.cost(grid, jnp.broadcast_to(fk, bctx.shape), corners)
        if respect_memory:
            infeasible = grid[None, None, :] > bctx.max_cut[None, :, None]
            cost = jnp.where(infeasible, jnp.inf, cost)
        return cost

    costs = jax.vmap(cost_at)(fgrid)                        # (F, R, D, C)
    n_dev = bctx.shape[1]
    flat = jnp.moveaxis(costs, 0, -1)                       # (R, D, C, F)
    flat = flat.reshape(bctx.shape + (bctx.n_cuts * n_freq,))
    idx = jnp.argmin(flat, axis=-1)
    best_c = (idx // n_freq).astype(jnp.int32)
    f_sel = fgrid[idx % n_freq, jnp.arange(n_dev)[None, :]]
    return _batched_evaluate(bctx, best_c, f_sel, corners)


def batched_server_only(bctx: BatchedRoundContext) -> BatchedDecision:
    cuts = jnp.zeros(bctx.shape, jnp.int32)
    return _batched_evaluate(bctx, cuts,
                             jnp.full(bctx.shape, bctx.server_f_max),
                             bctx.corners())


def batched_device_only(bctx: BatchedRoundContext) -> BatchedDecision:
    cuts = jnp.full(bctx.shape, bctx.n_cuts - 1, jnp.int32)
    f = jnp.broadcast_to(bctx.f_min(), bctx.shape)
    return _batched_evaluate(bctx, cuts, f, bctx.corners())


def batched_static_cut(bctx: BatchedRoundContext, cut) -> BatchedDecision:
    """``cut`` may be a scalar or an (R, D) array (e.g. random-cut draws)."""
    corners = bctx.corners()
    f_star = batched_optimal_frequency(bctx, corners)
    cuts = jnp.broadcast_to(jnp.asarray(cut, jnp.int32), bctx.shape)
    return _batched_evaluate(bctx, cuts, f_star, corners)
