"""CARD — Cut lAyer and computing Resource Decision (Alg. 1, Sec. IV).

  P2 -> (upper layer) closed-form server frequency, Eq. (16):
      f* = clip(Q, F_min^{m,S}, F_max^S),
      Q  = cbrt( w (E_max - E_min) / (2 xi (1-w) (D_max - D_min)) )
  P2 -> (lower layer) brute-force over c in {0..I}: O(I).

Baselines from Sec. V-B:
  server-only — c = 0 (device runs only the embedding module);
  device-only — c = I (device runs embedding + all decoders);
plus static-cut and random-cut baselines for wider comparison.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.core.cost_model import RoundContext


@dataclass(frozen=True)
class Decision:
    cut: int
    frequency: float
    cost: float
    delay: float
    energy: float


def optimal_frequency(ctx: RoundContext) -> float:
    """Eq. (16). Note Q is independent of c — the frequency subproblem and
    the cut subproblem decouple exactly as the paper exploits."""
    d_min, d_max, e_min, e_max = ctx.corners()
    w, xi = ctx.sim.w, ctx.sim.xi
    if w >= 1.0:
        return ctx.server.f_max
    q = ((w * (e_max - e_min))
         / (2.0 * xi * (1.0 - w) * max(d_max - d_min, 1e-12))) ** (1.0 / 3.0)
    return float(np.clip(q, ctx.f_min(), ctx.server.f_max))


def _evaluate(ctx: RoundContext, cut: int, f: float, corners) -> Decision:
    return Decision(cut=cut, frequency=f,
                    cost=ctx.cost(cut, f, corners),
                    delay=ctx.round_delay(cut, f),
                    energy=ctx.server_energy(cut, f))


def card(ctx: RoundContext, *, respect_memory: bool = True) -> Decision:
    """Alg. 1: f* once (line 1), then brute-force c (lines 3-9)."""
    corners = ctx.corners()
    f_star = optimal_frequency(ctx)
    max_cut = (ctx.max_feasible_cut() if respect_memory
               else ctx.workload.cfg.n_layers)
    best: Optional[Decision] = None
    for c in range(0, max_cut + 1):
        cand = _evaluate(ctx, c, f_star, corners)
        if best is None or cand.cost < best.cost:
            best = cand
    assert best is not None
    return best


def card_joint_bruteforce(ctx: RoundContext, *, n_freq: int = 200,
                          respect_memory: bool = True) -> Decision:
    """Exhaustive (f, c) grid — the optimality oracle for tests."""
    corners = ctx.corners()
    freqs = np.linspace(ctx.f_min(), ctx.server.f_max, n_freq)
    max_cut = (ctx.max_feasible_cut() if respect_memory
               else ctx.workload.cfg.n_layers)
    best: Optional[Decision] = None
    for c in range(0, max_cut + 1):
        for f in freqs:
            cand = _evaluate(ctx, c, float(f), corners)
            if best is None or cand.cost < best.cost:
                best = cand
    assert best is not None
    return best


# --- Benchmarks (Sec. V-B) ---------------------------------------------------


def server_only(ctx: RoundContext) -> Decision:
    """Devices fine-tune the embedding module only; server does the rest.
    Server runs flat out (no energy-aware DVFS) — the energy-hungry baseline."""
    return _evaluate(ctx, 0, ctx.server.f_max, ctx.corners())


def device_only(ctx: RoundContext) -> Decision:
    """Devices fine-tune embedding + all transformer decoders locally."""
    # device-only ignores the memory mask: that is precisely its weakness
    cut = ctx.workload.cfg.n_layers
    return _evaluate(ctx, cut, ctx.f_min(), ctx.corners())


def static_cut(ctx: RoundContext, cut: int) -> Decision:
    """Fixed split (the 'static strategies' the paper argues against)."""
    f_star = optimal_frequency(ctx)
    return _evaluate(ctx, cut, f_star, ctx.corners())


def random_cut(ctx: RoundContext, rng: np.random.Generator) -> Decision:
    cut = int(rng.integers(0, ctx.workload.cfg.n_layers + 1))
    return static_cut(ctx, cut)
