"""CARD — Cut lAyer and computing Resource Decision (Alg. 1, Sec. IV).

  P2 -> (upper layer) closed-form server frequency, Eq. (16):
      f* = clip(Q, F_min^{m,S}, F_max^S),
      Q  = cbrt( w (E_max - E_min) / (2 xi (1-w) (D_max - D_min)) )
  P2 -> (lower layer) brute-force over c in {0..I}: O(I).

Baselines from Sec. V-B:
  server-only — c = 0 (device runs only the embedding module);
  device-only — c = I (device runs embedding + all decoders);
plus static-cut and random-cut baselines for wider comparison.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import (BatchedRoundContext, RoundContext,
                                   TieredRoundContext)


@dataclass(frozen=True)
class Decision:
    """One device-round CARD decision: ``cut`` layers stay on the device,
    the server runs at ``frequency`` Hz; ``delay`` is the round time in
    seconds (Eq. 7), ``energy`` the server energy in joules (Eq. 11), and
    ``cost`` their Eq. 12 scalarization."""
    cut: int
    frequency: float
    cost: float
    delay: float
    energy: float


class DeadlineSpec(NamedTuple):
    """Straggler-aware deadline objective (churn extension, cf. Efficient
    Split Federated Learning, arXiv:2504.14667).

    The server closes rounds at a deadline; a decision whose nominal delay
    already exceeds it misses with probability 1, one that only misses when
    the device straggles (delay * mean slowdown > deadline) misses with the
    straggler probability, and every decision additionally risks the
    device's dropout. CARD's scalarized cost (Eq. 12) gains
    ``penalty * P(miss)`` so the (cut, f) choice trades delay/energy against
    the round actually committing. All fields are plain floats (jit data).
    """
    deadline_s: float
    p_dropout: float = 0.0
    p_straggler: float = 0.0
    slowdown: float = 1.0      # E[slowdown | straggler event]
    penalty: float = 1.0


def miss_probability(delay, spec: DeadlineSpec):
    """P(decision with nominal ``delay`` misses the round deadline) — jnp
    broadcasting over arbitrarily shaped delay tensors."""
    p_late = jnp.where(delay > spec.deadline_s, 1.0,
                       jnp.where(delay * spec.slowdown > spec.deadline_s,
                                 spec.p_straggler, 0.0))
    return spec.p_dropout + (1.0 - spec.p_dropout) * p_late


def _miss_probability_scalar(delay: float, spec: DeadlineSpec) -> float:
    """Float64 twin of :func:`miss_probability` for the scalar oracle."""
    if delay > spec.deadline_s:
        p_late = 1.0
    elif delay * spec.slowdown > spec.deadline_s:
        p_late = spec.p_straggler
    else:
        p_late = 0.0
    return spec.p_dropout + (1.0 - spec.p_dropout) * p_late


def optimal_frequency(ctx: RoundContext) -> float:
    """Eq. (16). Note Q is independent of c — the frequency subproblem and
    the cut subproblem decouple exactly as the paper exploits."""
    d_min, d_max, e_min, e_max = ctx.corners()
    w, xi = ctx.sim.w, ctx.sim.xi
    if w >= 1.0:
        return ctx.server.f_max
    q = ((w * (e_max - e_min))
         / (2.0 * xi * (1.0 - w) * max(d_max - d_min, 1e-12))) ** (1.0 / 3.0)
    return float(np.clip(q, ctx.f_min(), ctx.server.f_max))


def _evaluate(ctx: RoundContext, cut: int, f: float, corners,
              deadline: Optional[DeadlineSpec] = None) -> Decision:
    delay = ctx.round_delay(cut, f)
    cost = ctx.cost(cut, f, corners)
    if deadline is not None:
        cost += deadline.penalty * _miss_probability_scalar(delay, deadline)
    return Decision(cut=cut, frequency=f, cost=cost, delay=delay,
                    energy=ctx.server_energy(cut, f))


def card(ctx: RoundContext, *, respect_memory: bool = True,
         deadline: Optional[DeadlineSpec] = None) -> Decision:
    """Alg. 1: f* once (line 1), then brute-force c (lines 3-9).

    With ``deadline``, each candidate's cost is penalized by its round-miss
    probability (straggler-aware deadline objective), and every cut gains a
    *rescue* frequency candidate — the server flat out at ``f_max``, the
    delay-minimal point Eq. (16) cannot reach because its closed form does
    not see the deadline. The rescue wins only when its penalized cost is
    strictly lower, so a slack deadline reproduces nominal CARD exactly."""
    corners = ctx.corners()
    f_star = optimal_frequency(ctx)
    max_cut = (ctx.max_feasible_cut() if respect_memory
               else ctx.workload.cfg.n_layers)
    best: Optional[Decision] = None
    for c in range(0, max_cut + 1):
        cand = _evaluate(ctx, c, f_star, corners, deadline)
        if deadline is not None:
            rescue = _evaluate(ctx, c, ctx.server.f_max, corners, deadline)
            if rescue.cost < cand.cost:
                cand = rescue
        if best is None or cand.cost < best.cost:
            best = cand
    assert best is not None
    return best


def card_joint_bruteforce(ctx: RoundContext, *, n_freq: int = 200,
                          respect_memory: bool = True) -> Decision:
    """Exhaustive (f, c) grid — the optimality oracle for tests."""
    corners = ctx.corners()
    freqs = np.linspace(ctx.f_min(), ctx.server.f_max, n_freq)
    max_cut = (ctx.max_feasible_cut() if respect_memory
               else ctx.workload.cfg.n_layers)
    best: Optional[Decision] = None
    for c in range(0, max_cut + 1):
        for f in freqs:
            cand = _evaluate(ctx, c, float(f), corners)
            if best is None or cand.cost < best.cost:
                best = cand
    assert best is not None
    return best


# --- Benchmarks (Sec. V-B) ---------------------------------------------------


def server_only(ctx: RoundContext) -> Decision:
    """Devices fine-tune the embedding module only; server does the rest.
    Server runs flat out (no energy-aware DVFS) — the energy-hungry baseline."""
    return _evaluate(ctx, 0, ctx.server.f_max, ctx.corners())


def device_only(ctx: RoundContext) -> Decision:
    """Devices fine-tune embedding + all transformer decoders locally."""
    # device-only ignores the memory mask: that is precisely its weakness
    cut = ctx.workload.cfg.n_layers
    return _evaluate(ctx, cut, ctx.f_min(), ctx.corners())


def static_cut(ctx: RoundContext, cut: int) -> Decision:
    """Fixed split (the 'static strategies' the paper argues against)."""
    f_star = optimal_frequency(ctx)
    return _evaluate(ctx, cut, f_star, ctx.corners())


def random_cut(ctx: RoundContext, rng: np.random.Generator) -> Decision:
    """Baseline: uniform cut in [0, n_layers] from ``rng``, frequency
    still chosen by Eq. 16 (via ``static_cut``)."""
    cut = int(rng.integers(0, ctx.workload.cfg.n_layers + 1))
    return static_cut(ctx, cut)


# ---------------------------------------------------------------------------
# Batched CARD — the whole (rounds x devices x cuts) grid under jit
# ---------------------------------------------------------------------------


class BatchedDecision(NamedTuple):
    """Per-(round, device) decisions; every field is an (R, D) array."""
    cuts: jnp.ndarray         # int32
    freqs: jnp.ndarray        # Hz
    costs: jnp.ndarray        # Eq. 12 scalarized cost
    delays: jnp.ndarray       # Eq. 10 total round delay, s
    energies: jnp.ndarray     # Eq. 11 server energy, J
    d_device: jnp.ndarray     # delay breakdown: device compute
    d_uplink: jnp.ndarray     #                  uplink (smashed + adapters)
    d_server: jnp.ndarray     #                  server compute
    d_downlink: jnp.ndarray   #                  downlink (grads + adapters)


def batched_optimal_frequency(bctx: BatchedRoundContext,
                              corners=None) -> jnp.ndarray:
    """Eq. (16) per (round, device): Q depends only on the corners, which
    depend on the channel draw — hence an (R, D) array of f*."""
    if corners is None:
        corners = bctx.corners()
    d_min, d_max, e_min, e_max = corners
    # w is traced (see BatchedRoundContext): guard the 1-w division and
    # select the pure-delay w=1 endpoint with where, not Python control flow
    q = ((bctx.w * (e_max - e_min))
         / (2.0 * bctx.xi * jnp.maximum(1.0 - bctx.w, 1e-12)
            * jnp.maximum(d_max - d_min, 1e-12))) ** (1.0 / 3.0)
    f = jnp.clip(q, bctx.f_min()[None, :], bctx.server_f_max)
    return jnp.where(bctx.w >= 1.0, bctx.server_f_max, f)


def _batched_evaluate(bctx: BatchedRoundContext, cuts: jnp.ndarray,
                      f: jnp.ndarray, corners,
                      deadline: Optional[DeadlineSpec] = None
                      ) -> BatchedDecision:
    """Metrics for fixed per-(round, device) decisions (cuts, f): (R, D)."""
    c = cuts[..., None]
    parts = bctx.delay_components(c, f)
    delays = parts.total[..., 0]
    costs = bctx.cost(c, f, corners)[..., 0]
    if deadline is not None:
        costs = costs + deadline.penalty * miss_probability(delays, deadline)
    return BatchedDecision(
        cuts=cuts.astype(jnp.int32),
        freqs=jnp.broadcast_to(f, bctx.shape),
        costs=costs,
        delays=delays,
        energies=bctx.server_energy(c, f)[..., 0],
        d_device=parts.device_comp[..., 0], d_uplink=parts.uplink[..., 0],
        d_server=parts.server_comp[..., 0], d_downlink=parts.downlink[..., 0])


@partial(jax.jit, static_argnames=("respect_memory",))
def batched_card(bctx: BatchedRoundContext, *,
                 respect_memory: bool = True,
                 deadline: Optional[DeadlineSpec] = None) -> BatchedDecision:
    """Alg. 1 for the whole fleet: closed-form f* per (round, device), then
    the brute-force over cuts becomes one argmin over the cost tensor.
    ``deadline`` adds the straggler-aware miss-probability penalty and the
    per-cut f_max rescue candidate (same objective as the scalar path)."""
    corners = bctx.corners()
    f_star = batched_optimal_frequency(bctx, corners)
    grid = jnp.arange(bctx.n_cuts)
    freqs = f_star
    cost = bctx.cost(grid, f_star, corners)                 # (R, D, C)
    # structural None checks below: recompile only when the deadline
    # objective is toggled on/off, never per value
    # splint: ignore[trace-safety]
    if deadline is not None:
        def penalized(f):
            base = bctx.cost(grid, f, corners)              # (R, D, C)
            return base + deadline.penalty * miss_probability(
                bctx.round_delay(grid, f), deadline)

        cost = penalized(f_star)
        rescue_cost = penalized(jnp.full(bctx.shape, bctx.server_f_max))
        use_rescue = rescue_cost < cost                     # strict, like
        cost = jnp.where(use_rescue, rescue_cost, cost)     # the scalar path
    if respect_memory:
        infeasible = grid[None, None, :] > bctx.max_cut[None, :, None]
        cost = jnp.where(infeasible, jnp.inf, cost)
    best = jnp.argmin(cost, axis=-1).astype(jnp.int32)      # (R, D)
    # splint: ignore[trace-safety]
    if deadline is not None:
        picked = jnp.take_along_axis(use_rescue, best[..., None],
                                     axis=-1)[..., 0]
        freqs = jnp.where(picked, bctx.server_f_max, f_star)
    return _batched_evaluate(bctx, best, freqs, corners, deadline)


@partial(jax.jit, static_argnames=("n_freq", "respect_memory"))
def batched_card_joint_bruteforce(bctx: BatchedRoundContext, *,
                                  n_freq: int = 200,
                                  respect_memory: bool = True
                                  ) -> BatchedDecision:
    """Exhaustive (f, c) grid, vmapped over the frequency axis — the
    optimality oracle for the batched path. O(F * R * D * C) memory: use
    small fleets (tests), not production sweeps."""
    corners = bctx.corners()
    grid = jnp.arange(bctx.n_cuts)
    fgrid = jnp.linspace(bctx.f_min(), bctx.server_f_max, n_freq)  # (F, D)

    def cost_at(fk):
        cost = bctx.cost(grid, jnp.broadcast_to(fk, bctx.shape), corners)
        if respect_memory:
            infeasible = grid[None, None, :] > bctx.max_cut[None, :, None]
            cost = jnp.where(infeasible, jnp.inf, cost)
        return cost

    costs = jax.vmap(cost_at)(fgrid)                        # (F, R, D, C)
    n_dev = bctx.shape[1]
    flat = jnp.moveaxis(costs, 0, -1)                       # (R, D, C, F)
    flat = flat.reshape(bctx.shape + (bctx.n_cuts * n_freq,))
    idx = jnp.argmin(flat, axis=-1)
    best_c = (idx // n_freq).astype(jnp.int32)
    f_sel = fgrid[idx % n_freq, jnp.arange(n_dev)[None, :]]
    return _batched_evaluate(bctx, best_c, f_sel, corners)


def batched_server_only(bctx: BatchedRoundContext) -> BatchedDecision:
    """Baseline: cut 0 (everything on the server) at ``server_f_max`` Hz
    for every (round, device) lane; all outputs are (R, D)."""
    cuts = jnp.zeros(bctx.shape, jnp.int32)
    return _batched_evaluate(bctx, cuts,
                             jnp.full(bctx.shape, bctx.server_f_max),
                             bctx.corners())


def batched_device_only(bctx: BatchedRoundContext) -> BatchedDecision:
    """Baseline: cut n_layers (everything on the device) at the minimum
    feasible server frequency in Hz; all outputs are (R, D)."""
    cuts = jnp.full(bctx.shape, bctx.n_cuts - 1, jnp.int32)
    f = jnp.broadcast_to(bctx.f_min(), bctx.shape)
    return _batched_evaluate(bctx, cuts, f, bctx.corners())


def batched_static_cut(bctx: BatchedRoundContext, cut) -> BatchedDecision:
    """``cut`` may be a scalar or an (R, D) array (e.g. random-cut draws)."""
    corners = bctx.corners()
    f_star = batched_optimal_frequency(bctx, corners)
    cuts = jnp.broadcast_to(jnp.asarray(cut, jnp.int32), bctx.shape)
    return _batched_evaluate(bctx, cuts, f_star, corners)


# ---------------------------------------------------------------------------
# Hierarchical CARD — a tier of servers with device -> server assignment
# ---------------------------------------------------------------------------
#
# SplitLLM (arXiv:2501.13318) hierarchical setting: stage 1 assigns each
# device to one server of the tier (capacity-constrained), stage 2 runs
# per-server CARD exactly as before. The assignment objective is device-
# separable — each (server, device) pair is priced at the device's *optimal*
# CARD cost under that server (mean over the round batch) — so the two
# stages decouple the same way Eq. 16 decouples f from c: the per-server
# grids are computed once for all S servers and the assignment is a pure
# host-side matching over the (S, D) price matrix.


class TierDecision(NamedTuple):
    """Per-(server, round, device) best-response CARD grids; every field is
    an (S, R, D) array. ``costs`` is what the assignment stage prices;
    ``delays`` are seconds, ``energies`` joules, ``freqs`` Hz. ``d_server``
    is the server-compute share of ``delays`` — the term that contends when
    one server hosts many devices (parallel-SL round folding)."""
    cuts: jnp.ndarray
    freqs: jnp.ndarray
    costs: jnp.ndarray
    delays: jnp.ndarray
    energies: jnp.ndarray
    d_server: jnp.ndarray


def tiered_optimal_frequency(tctx: TieredRoundContext,
                             corners=None) -> jnp.ndarray:
    """Eq. (16) per (server, round, device): same closed form as
    :func:`batched_optimal_frequency` with per-server DVFS bounds."""
    if corners is None:
        corners = tctx.corners()
    d_min, d_max, e_min, e_max = corners
    q = ((tctx.w * (e_max - e_min))
         / (2.0 * tctx.xi * jnp.maximum(1.0 - tctx.w, 1e-12)
            * jnp.maximum(d_max - d_min, 1e-12))) ** (1.0 / 3.0)
    f_hi = jnp.broadcast_to(tctx.server_f_max[:, None, None], tctx.shape)
    f = jnp.clip(q, tctx.f_min()[:, None, :], f_hi)
    return jnp.where(tctx.w >= 1.0, f_hi, f)


@partial(jax.jit, static_argnames=("respect_memory",))
def tiered_card_grid(tctx: TieredRoundContext, *,
                     respect_memory: bool = True) -> TierDecision:
    """Alg. 1 for every candidate server at once: closed-form f* per
    (server, round, device), then one argmin over the (S, R, D, C) cost
    tensor. Stage 2 of ``hierarchical_card`` — and, gathered along the
    assignment, identical to running ``batched_card`` per server."""
    corners = tctx.corners()
    f_star = tiered_optimal_frequency(tctx, corners)
    grid = jnp.arange(tctx.n_cuts)
    cost = tctx.cost(grid, f_star, corners)                  # (S, R, D, C)
    if respect_memory:
        infeasible = grid[None, None, None, :] \
            > tctx.max_cut[None, None, :, None]
        cost = jnp.where(infeasible, jnp.inf, cost)
    best = jnp.argmin(cost, axis=-1).astype(jnp.int32)       # (S, R, D)
    c = best[..., None]
    parts = tctx.delay_components(c, f_star)
    return TierDecision(
        cuts=best,
        freqs=f_star,
        costs=tctx.cost(c, f_star, corners)[..., 0],
        delays=parts.total[..., 0],
        energies=tctx.server_energy(c, f_star)[..., 0],
        d_server=parts.server_comp[..., 0])


ASSIGN_METHODS = ("greedy", "optimal")


def assign_devices(cost_sd: np.ndarray, capacity: np.ndarray, *,
                   method: str = "greedy") -> np.ndarray:
    """Capacity-constrained device -> server assignment over an (S, D)
    price matrix (float64, NaN/inf = infeasible pair). Returns (D,) int.

    ``"greedy"`` — regret-ordered auction-style pass: devices bid in order
    of decreasing regret (second-best minus best price) and take the
    cheapest server with remaining capacity. Optimal whenever no capacity
    binds (then it degenerates to the per-device argmin); a heuristic
    otherwise — the O(D log D + D S) path for million-device fleets.

    ``"optimal"`` — successive-shortest-path min-cost matching (unit-supply
    transportation problem): devices are assigned one at a time via the
    cheapest chain of reassignments in the residual graph. Exactly optimal
    (the residual graph stays free of negative cycles, the SSP invariant);
    O(D * S^2 * D) worst case — the oracle for tests and small tiers, not
    the million-device path.
    """
    cost_sd = np.asarray(cost_sd, np.float64)
    n_servers, n_devices = cost_sd.shape
    capacity = np.asarray(capacity, np.int64)
    if capacity.shape != (n_servers,):
        raise ValueError(f"capacity shape {capacity.shape} != ({n_servers},)")
    if capacity.sum() < n_devices:
        raise ValueError(f"tier capacity {int(capacity.sum())} < "
                         f"{n_devices} devices")
    if method == "greedy":
        return _assign_greedy(cost_sd, capacity)
    if method == "optimal":
        return _assign_optimal(cost_sd, capacity)
    raise ValueError(f"unknown assignment method {method!r}; "
                     f"expected one of {ASSIGN_METHODS}")


def _assign_greedy(cost_sd: np.ndarray, capacity: np.ndarray) -> np.ndarray:
    n_servers, n_devices = cost_sd.shape
    finite = np.where(np.isfinite(cost_sd), cost_sd, np.inf)
    if n_servers > 1:
        part = np.partition(finite, 1, axis=0)
        regret = part[1] - part[0]                   # (D,)
        regret = np.where(np.isfinite(regret), regret, np.inf)
    else:
        regret = np.zeros(n_devices)
    remaining = capacity.copy()
    assign = np.full(n_devices, -1, np.int64)
    # stable argsort on -regret: ties resolve by device index, deterministic
    for d in np.argsort(-regret, kind="stable"):
        for s in np.argsort(finite[:, d], kind="stable"):
            if remaining[s] > 0 and np.isfinite(finite[s, d]):
                assign[d] = s
                remaining[s] -= 1
                break
        if assign[d] < 0:
            raise ValueError(f"device {d} has no feasible server with "
                             "remaining capacity")
    return assign


def _assign_optimal(cost_sd: np.ndarray, capacity: np.ndarray) -> np.ndarray:
    """Successive shortest augmenting paths (Bellman-Ford over the server
    nodes; path length <= S-1 because the residual graph of a partial
    optimum has no negative cycles)."""
    n_servers, n_devices = cost_sd.shape
    remaining = capacity.copy()
    assign = np.full(n_devices, -1, np.int64)
    members: list = [[] for _ in range(n_servers)]
    for d in range(n_devices):
        dist = np.where(np.isfinite(cost_sd[:, d]), cost_sd[:, d], np.inf)
        pred: list = [None] * n_servers       # (prev_server, moved_device)
        for _ in range(n_servers - 1):
            changed = False
            for s in range(n_servers):
                if not np.isfinite(dist[s]) or not members[s]:
                    continue
                ds = np.asarray(members[s])
                # moving device d' from s to s2 costs c[s2,d'] - c[s,d']
                delta = cost_sd[:, ds] - cost_sd[s, ds][None, :]  # (S, |ds|)
                j = np.nanargmin(np.where(np.isfinite(delta), delta, np.inf),
                                 axis=1)
                step = delta[np.arange(n_servers), j]
                nd = dist[s] + step
                upd = np.isfinite(nd) & (nd < dist - 1e-15)
                for s2 in np.nonzero(upd)[0]:
                    dist[s2] = nd[s2]
                    pred[s2] = (s, int(ds[j[s2]]))
                    changed = True
            if not changed:
                break
        open_servers = np.nonzero(remaining > 0)[0]
        if open_servers.size == 0 or not np.isfinite(
                dist[open_servers]).any():
            raise ValueError(f"device {d} has no feasible augmenting path")
        target = int(open_servers[np.argmin(dist[open_servers])])
        # walk the chain of reassignments back to the direct edge
        s = target
        while pred[s] is not None:
            prev_s, moved = pred[s]
            members[prev_s].remove(moved)
            members[s].append(moved)
            assign[moved] = s
            s = prev_s
        members[s].append(d)
        assign[d] = s
        remaining[target] -= 1
    return assign


def exhaustive_assignment(cost_sd: np.ndarray,
                          capacity: np.ndarray) -> np.ndarray:
    """Brute-force over all S^D capacity-feasible assignments — the oracle
    ``_assign_optimal`` is tested against. Lexicographically-first argmin,
    strictly for small fleets (<= ~8 devices)."""
    import itertools
    cost_sd = np.asarray(cost_sd, np.float64)
    n_servers, n_devices = cost_sd.shape
    if n_servers ** n_devices > 2_000_000:
        raise ValueError(f"{n_servers}^{n_devices} assignments is too many "
                         "to enumerate — use assign_devices")
    best_total, best = np.inf, None
    dev_idx = np.arange(n_devices)
    for combo in itertools.product(range(n_servers), repeat=n_devices):
        a = np.asarray(combo)
        counts = np.bincount(a, minlength=n_servers)
        if (counts > capacity).any():
            continue
        total = cost_sd[a, dev_idx].sum()
        if total < best_total - 1e-15:
            best_total, best = total, a
    if best is None:
        raise ValueError("no capacity-feasible assignment exists")
    return best


class HierarchicalDecision(NamedTuple):
    """The hierarchical_card result.

    ``assignment`` — (D,) int server index per device; ``cuts``/``freqs``/
    ``costs``/``delays``/``energies``/``d_server`` — (R, D) per-device
    decisions under the assigned server (seconds / joules / Hz, as in
    BatchedDecision; ``d_server`` is the server-compute share of
    ``delays``); ``aggregation_s`` — (S, R) per-server backhaul aggregation
    delay; ``server_load`` — (S,) devices per server.
    """
    assignment: np.ndarray
    cuts: np.ndarray
    freqs: np.ndarray
    costs: np.ndarray
    delays: np.ndarray
    energies: np.ndarray
    d_server: np.ndarray
    aggregation_s: np.ndarray
    server_load: np.ndarray


def _gather_assigned(grid: TierDecision, assign: np.ndarray
                     ) -> Dict[str, np.ndarray]:
    """Select each device's (R,) lane from its assigned server's grid."""
    host = jax.device_get(grid)
    n_devices = assign.shape[0]
    dev_idx = np.arange(n_devices)
    return {field: np.asarray(getattr(host, field))[assign, :, dev_idx].T
            for field in TierDecision._fields}


def hierarchical_card(tctx: TieredRoundContext, *,
                      respect_memory: bool = True,
                      assign: str = "greedy") -> HierarchicalDecision:
    """Two-stage hierarchical CARD (fleet-of-fleets):

    1. price every (server, device) pair at the device's optimal CARD cost
       under that server (one jitted (S, R, D, C) grid, mean over rounds),
    2. assign devices to servers under the tier's capacity
       (:func:`assign_devices`, ``assign="greedy" | "optimal"``),
    3. read each device's per-round (cut, f) decision off its assigned
       server's grid and price the per-server backhaul aggregation.

    Decision-equivalent to exhaustive assignment enumeration for
    ``assign="optimal"`` (tested on fleets <= 8 devices x 2 servers).
    """
    grid = tiered_card_grid(tctx, respect_memory=respect_memory)
    cost_sd = np.asarray(jax.device_get(grid.costs), np.float64).mean(axis=1)
    a = assign_devices(cost_sd, np.asarray(tctx.capacity), method=assign)
    picked = _gather_assigned(grid, a)
    assign_mask = a[None, :] == np.arange(tctx.n_servers)[:, None]
    agg = jax.device_get(tctx.aggregation_delay(
        jnp.asarray(assign_mask), jnp.asarray(picked["cuts"])))
    return HierarchicalDecision(
        assignment=a.astype(np.int64),
        cuts=picked["cuts"].astype(np.int32),
        freqs=picked["freqs"], costs=picked["costs"],
        delays=picked["delays"], energies=picked["energies"],
        d_server=picked["d_server"],
        aggregation_s=np.asarray(agg),
        server_load=assign_mask.sum(axis=1).astype(np.int64))


def hierarchical_card_exhaustive(tctx: TieredRoundContext, *,
                                 respect_memory: bool = True
                                 ) -> HierarchicalDecision:
    """The test oracle: exhaustive assignment enumeration over the same
    price matrix, then the identical per-server decision readout."""
    grid = tiered_card_grid(tctx, respect_memory=respect_memory)
    cost_sd = np.asarray(jax.device_get(grid.costs), np.float64).mean(axis=1)
    a = exhaustive_assignment(cost_sd, np.asarray(tctx.capacity))
    picked = _gather_assigned(grid, a)
    assign_mask = a[None, :] == np.arange(tctx.n_servers)[:, None]
    agg = jax.device_get(tctx.aggregation_delay(
        jnp.asarray(assign_mask), jnp.asarray(picked["cuts"])))
    return HierarchicalDecision(
        assignment=a.astype(np.int64),
        cuts=picked["cuts"].astype(np.int32),
        freqs=picked["freqs"], costs=picked["costs"],
        delays=picked["delays"], energies=picked["energies"],
        d_server=picked["d_server"],
        aggregation_s=np.asarray(agg),
        server_load=assign_mask.sum(axis=1).astype(np.int64))


def hierarchical_card_scalar(workload, devices, tier, channels, sim, *,
                             respect_memory: bool = True,
                             assign: str = "optimal") -> HierarchicalDecision:
    """Float64 scalar reference oracle for :func:`hierarchical_card`: the
    per-(server, device, round) grids come from the scalar ``card`` loop
    (``RoundContext`` per cell), the assignment from the same matcher.

    ``channels`` is a ``ChannelBatch`` — both paths must consume identical
    link realizations, exactly like the flat engines.
    """
    n_servers = tier.n_servers
    rounds, n_devices = channels.rate_up.shape
    cost_sRD = np.zeros((n_servers, rounds, n_devices))
    cuts = np.zeros((n_servers, rounds, n_devices), np.int32)
    freqs = np.zeros((n_servers, rounds, n_devices))
    delays = np.zeros((n_servers, rounds, n_devices))
    energies = np.zeros((n_servers, rounds, n_devices))
    d_srv = np.zeros((n_servers, rounds, n_devices))
    for s, server in enumerate(tier.servers):
        for m, dev in enumerate(devices):
            for r in range(rounds):
                ctx = RoundContext(workload=workload, device=dev,
                                   server=server,
                                   channel=channels.state(r, m), sim=sim)
                d = card(ctx, respect_memory=respect_memory)
                cost_sRD[s, r, m] = d.cost
                cuts[s, r, m] = d.cut
                freqs[s, r, m] = d.frequency
                delays[s, r, m] = d.delay
                energies[s, r, m] = d.energy
                d_srv[s, r, m] = ctx.delay_components(
                    d.cut, d.frequency).server_comp
    cost_sd = cost_sRD.mean(axis=1)
    a = assign_devices(cost_sd, np.asarray(tier.capacity), method=assign)
    dev_idx = np.arange(n_devices)
    pick = lambda x: x[a, :, dev_idx].T                       # noqa: E731
    picked_cuts = pick(cuts)
    assign_mask = a[None, :] == np.arange(n_servers)[:, None]
    adapter_bits = np.array([8 * workload.adapter_bytes(c, sim.adapter_bytes)
                             for c in range(workload.cfg.n_layers + 1)])
    bits = adapter_bits[picked_cuts]                          # (R, D)
    backhaul = np.asarray(tier.backhaul_bits_per_s)
    agg = (np.where(assign_mask[:, None, :], bits[None], 0.0).sum(axis=-1)
           / backhaul[:, None])
    return HierarchicalDecision(
        assignment=a.astype(np.int64), cuts=picked_cuts,
        freqs=pick(freqs), costs=pick(cost_sRD), delays=pick(delays),
        energies=pick(energies), d_server=pick(d_srv), aggregation_s=agg,
        server_load=assign_mask.sum(axis=1).astype(np.int64))
