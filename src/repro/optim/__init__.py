from repro.optim.optimizer import (adamw, sgd, apply_updates, Optimizer,
                                   cosine_schedule, constant_schedule,
                                   warmup_cosine)  # noqa: F401
