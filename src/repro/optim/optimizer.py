"""Minimal optax-style optimizers (optax is not installed offline).

Pure pytree transforms: ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``. LoRA-only masking is
structural — the trainable tree *is* the LoRA tree, so no mask is needed;
the frozen backbone never enters the optimizer.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1
                    ) -> Schedule:
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(lr: float, warmup: int, total_steps: int,
                  final_frac: float = 0.1) -> Schedule:
    cos = cosine_schedule(lr, max(total_steps - warmup, 1), final_frac)
    def fn(step):
        warm = lr * step / max(warmup, 1)
        return jnp.where(step < warmup, warm, cos(step - warmup))
    return fn


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


def sgd(schedule: Schedule, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"mu": jax.tree_util.tree_map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        del params
        step = state["step"] + 1
        lr = schedule(step)
        mu = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g, state["mu"], grads)
        updates = jax.tree_util.tree_map(lambda m: -lr * m, mu)
        return updates, {"mu": mu, "step": step}

    return Optimizer(init, update)


def adamw(schedule: Schedule, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = schedule(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)

        def upd(m_, v_, p):
            mhat = m_ / bc1
            vhat = v_ / bc2
            u = -lr * (mhat / (jnp.sqrt(vhat) + eps)
                       + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)
