"""``python -m repro`` — the CLI wiring the README quickstart points at.

Thin argparse front-end over the decision stack; heavy imports (JAX) are
deferred into the subcommand handlers so ``--help`` stays instant and
import-smoke checks (CI ``docs`` job) need no accelerator warm-up.

Subcommands::

    python -m repro archs                    # list the model registry
    python -m repro sweep --devices 100      # vectorized fleet sweep
    python -m repro hierarchy --servers 4    # multi-server tier sweep
"""
from __future__ import annotations

import argparse
import sys


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro",
        description=("Energy-efficient split learning for LLM fine-tuning "
                     "in edge networks — CARD decision stack CLI."))
    sub = p.add_subparsers(dest="command")

    sub.add_parser("archs", help="list registered model architectures")

    sweep = sub.add_parser(
        "sweep", help="run a vectorized fleet sweep (simulate_fleet)")
    sweep.add_argument("--arch", default="llama32-1b",
                       help="model architecture id (see `archs`)")
    sweep.add_argument("--policy", default="card",
                       choices=("card", "server_only", "device_only",
                                "random", "static"),
                       help="cut/frequency policy")
    sweep.add_argument("--rounds", type=int, default=10)
    sweep.add_argument("--devices", type=int, default=100,
                       help="fleet size (heterogeneous, seeded)")
    sweep.add_argument("--channel", default="normal",
                       help="channel state (e.g. good / normal / poor)")
    sweep.add_argument("--seed", type=int, default=0)
    sweep.add_argument("--shards", type=int, default=0,
                       help="shard the devices axis over N host devices "
                            "(0 = unsharded)")

    hier = sub.add_parser(
        "hierarchy",
        help="run a multi-server tier sweep (simulate_hierarchical_fleet)")
    hier.add_argument("--arch", default="llama32-1b")
    hier.add_argument("--servers", type=int, default=2)
    hier.add_argument("--capacity", type=int, default=0,
                      help="per-server device capacity (0 = fleet/servers, "
                           "rounded up)")
    hier.add_argument("--rounds", type=int, default=10)
    hier.add_argument("--devices", type=int, default=100)
    hier.add_argument("--channel", default="normal")
    hier.add_argument("--seed", type=int, default=0)
    hier.add_argument("--assign", default="greedy",
                      choices=("greedy", "optimal"))
    return p


def _cmd_archs() -> int:
    from repro.configs.base import ARCH_IDS, get_config
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        print(f"{arch:24s} {cfg.family:8s} {cfg.n_layers:3d} layers  "
              f"d_model={cfg.d_model}")
    return 0


def _cmd_sweep(args: argparse.Namespace) -> int:
    from repro.configs.base import get_config
    from repro.core.hardware import make_heterogeneous_fleet
    from repro.core.scheduler import simulate_fleet

    mesh = None
    if args.shards:
        from repro.launch.mesh import make_fleet_mesh
        mesh = make_fleet_mesh(args.shards)
    fleet = make_heterogeneous_fleet(args.devices, seed=args.seed)
    log = simulate_fleet(get_config(args.arch), policy=args.policy,
                         rounds=args.rounds, devices=fleet,
                         channel_state=args.channel, seed=args.seed,
                         mesh=mesh)
    print(f"policy={log.policy} arch={args.arch} "
          f"rounds={args.rounds} devices={args.devices}"
          + (f" shards={args.shards}" if args.shards else ""))
    print(f"mean delay   {log.mean_delay():12.3f} s")
    print(f"mean energy  {log.mean_energy():12.3f} J")
    return 0


def _cmd_hierarchy(args: argparse.Namespace) -> int:
    from repro.configs.base import get_config
    from repro.core.hardware import make_heterogeneous_fleet, make_server_tier
    from repro.core.scheduler import simulate_hierarchical_fleet

    capacity = args.capacity or -(-args.devices // args.servers)
    tier = make_server_tier(args.servers, capacity=capacity, seed=args.seed)
    fleet = make_heterogeneous_fleet(args.devices, seed=args.seed)
    hlog = simulate_hierarchical_fleet(
        get_config(args.arch), tier=tier, rounds=args.rounds, devices=fleet,
        channel_state=args.channel, seed=args.seed, assign=args.assign)
    print(f"servers={args.servers} capacity={capacity} "
          f"devices={args.devices} rounds={args.rounds} "
          f"assign={args.assign}")
    print(f"mean round   {hlog.mean_round_s():12.3f} s")
    print(f"mean delay   {hlog.mean_delay():12.3f} s")
    print(f"mean energy  {hlog.mean_energy():12.3f} J")
    return 0


def main(argv=None) -> int:
    parser = _build_parser()
    args = parser.parse_args(argv)
    if args.command is None:
        parser.print_help()
        return 0
    if args.command == "archs":
        return _cmd_archs()
    if args.command == "sweep":
        return _cmd_sweep(args)
    return _cmd_hierarchy(args)


if __name__ == "__main__":
    sys.exit(main())
