"""Markdown link-check for the documentation layer (CI ``docs`` job).

Pure stdlib. Scans the given markdown files/directories for inline links
and images, and fails (exit 1) when a relative link points at a file that
does not exist, or an intra-repo anchor (``#heading``) names a heading
that is not in the target file. External links (``http(s)://``,
``mailto:``) are skipped — CI must not flake on someone else's server.

Usage::

    python tools/check_docs.py README.md docs benchmarks/README.md ROADMAP.md
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path
from typing import List, Tuple

# inline [text](target) and ![alt](target); stops at the first unescaped ')'
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$")
_CODE_FENCE = re.compile(r"^(```|~~~)")
_EXTERNAL = ("http://", "https://", "mailto:")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, strip punctuation, spaces->dashes."""
    text = re.sub(r"[`*_~\[\]()!]", "", heading.strip()).lower()
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return re.sub(r" ", "-", text)


def iter_md_files(paths: List[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.md")))
        elif path.suffix == ".md" and path.exists():
            out.append(path)
        else:
            print(f"check_docs: no such markdown input: {p}",
                  file=sys.stderr)
            sys.exit(2)
    return out


def extract_links(text: str) -> List[Tuple[int, str]]:
    """(line_number, target) for every inline link outside code fences."""
    links: List[Tuple[int, str]] = []
    in_fence = False
    for i, line in enumerate(text.splitlines(), start=1):
        if _CODE_FENCE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK.finditer(line):
            links.append((i, m.group(1)))
    return links


def anchors_of(path: Path) -> set:
    slugs = set()
    in_fence = False
    for line in path.read_text().splitlines():
        if _CODE_FENCE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING.match(line)
        if m:
            slugs.add(github_slug(m.group(1)))
    return slugs


def check_file(md: Path, repo_root: Path) -> List[str]:
    errors: List[str] = []
    for line_no, target in extract_links(md.read_text()):
        if target.startswith(_EXTERNAL) or target.startswith("<"):
            continue
        path_part, _, anchor = target.partition("#")
        if not path_part:           # same-file anchor
            dest = md
        else:
            dest = (md.parent / path_part).resolve()
            try:
                dest.relative_to(repo_root)
            except ValueError:
                errors.append(f"{md}:{line_no}: link escapes the repo: "
                              f"{target}")
                continue
            if not dest.exists():
                errors.append(f"{md}:{line_no}: broken link: {target}")
                continue
        if anchor and dest.suffix == ".md" and dest.is_file():
            if github_slug(anchor) not in anchors_of(dest):
                errors.append(f"{md}:{line_no}: missing anchor "
                              f"#{anchor} in {dest.name}")
    return errors


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("paths", nargs="+",
                        help="markdown files and/or directories")
    args = parser.parse_args(argv)
    repo_root = Path.cwd().resolve()
    files = iter_md_files(args.paths)
    errors: List[str] = []
    for md in files:
        errors.extend(check_file(md, repo_root))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_docs: {len(files)} file(s), {len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
