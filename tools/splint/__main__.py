"""splint CLI.

Usage:
    python -m tools.splint src benchmarks tests \
        --baseline tools/splint/baseline.json --json splint_report.json

Exit status is 1 iff there are unsuppressed findings not covered by the
baseline.  ``--write-baseline`` accepts the current findings as the new
baseline (the ratchet reset — review the diff before committing it).
``--all`` prints baselined findings too.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.splint import engine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="splint",
        description="repo-native static analysis (JAX trace-safety, "
                    "Pallas constraints, unit suffixes)")
    ap.add_argument("paths", nargs="+", help="files or directories to scan")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline JSON of accepted findings")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from the current findings")
    ap.add_argument("--json", type=Path, default=None, metavar="OUT",
                    help="write the machine-readable report here")
    ap.add_argument("--all", action="store_true",
                    help="also print baselined findings")
    args = ap.parse_args(argv)

    result = engine.scan_files(args.paths)

    if args.write_baseline:
        if args.baseline is None:
            ap.error("--write-baseline requires --baseline")
        counts = engine.write_baseline(args.baseline, result.findings)
        print(f"splint: wrote {sum(counts.values())} finding(s) "
              f"({len(counts)} fingerprint(s)) to {args.baseline}")
        return 0

    baseline = engine.load_baseline(args.baseline)
    new, baselined = engine.split_new(result.findings, baseline)

    for f in new:
        print(f.format())
    if args.all:
        for f in baselined:
            print(f"{f.format()} [baselined]")

    if args.json:
        args.json.write_text(
            json.dumps(engine.report_dict(result, new, baselined),
                       indent=1) + "\n")

    stale = sum(baseline.values()) - len(baselined)
    summary = (f"splint: {result.files_scanned} file(s), "
               f"{len(new)} new, {len(baselined)} baselined, "
               f"{len(result.suppressed)} suppressed")
    if stale > 0:
        summary += (f"; {stale} baseline entr(y/ies) no longer fire "
                    f"— re-run with --write-baseline to ratchet down")
    print(summary)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
