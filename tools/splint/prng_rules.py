"""prng-reuse rule: jax.random keys consumed more than once or in loops.

A ``jax.random`` consumer (``normal``, ``uniform``, ``bernoulli``, ...)
must see each key exactly once; reusing one silently correlates samples
(fleet channel draws that should be i.i.d. come out identical).

Flagged, per function scope:

* the *same key expression* (textually, e.g. ``rng`` or
  ``jax.random.PRNGKey(0)``) passed to two or more consumer calls —
  ``keys[0]`` / ``keys[1]`` after a ``split`` are distinct and fine;
* a consumer inside a ``for``/``while`` whose key expression involves no
  loop-varying name (not the loop target, never reassigned in the body):
  every iteration draws from the same stream.  Re-splitting
  (``key, sub = jax.random.split(key)``) or indexing by the loop
  variable both count as varying.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from tools.splint.engine import Finding, call_name, parent_of

RULE = "prng-reuse"

_NONCONSUMERS = {"split", "fold_in", "PRNGKey", "key", "key_data",
                 "wrap_key_data", "key_impl", "clone"}


def _random_roots(tree: ast.AST) -> Tuple[Set[str], Set[str]]:
    """(module roots like ``jax.random.``, bare consumer names) resolved
    from the file's imports — ``import random`` (stdlib) never matches."""
    roots = {"jax.random."}
    bare: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "jax.random" and a.asname:
                    roots.add(a.asname + ".")
        elif isinstance(node, ast.ImportFrom):
            if node.module == "jax":
                for a in node.names:
                    if a.name == "random":
                        roots.add((a.asname or a.name) + ".")
            elif node.module == "jax.random":
                for a in node.names:
                    if a.name not in _NONCONSUMERS:
                        bare.add(a.asname or a.name)
    return roots, bare


def _consumer_name(node: ast.Call, roots: Set[str],
                   bare: Set[str]) -> Optional[str]:
    name = call_name(node)
    if not name:
        return None
    if name in bare:
        return name
    for root in roots:
        if name.startswith(root):
            tail = name[len(root):]
            if "." not in tail and tail not in _NONCONSUMERS:
                return name
    return None


def _key_expr(node: ast.Call) -> Optional[ast.AST]:
    if node.args:
        return node.args[0]
    for kw in node.keywords:
        if kw.arg == "key":
            return kw.value
    return None


def _names_in(expr: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(expr) if isinstance(n, ast.Name)}


def _enclosing_fn(node: ast.AST):
    p = parent_of(node)
    while p is not None and not isinstance(
            p, (ast.FunctionDef, ast.AsyncFunctionDef)):
        p = parent_of(p)
    return p


def _enclosing_loop(node: ast.AST, stop_at) -> Optional[ast.AST]:
    p = parent_of(node)
    while p is not None and p is not stop_at:
        if isinstance(p, (ast.For, ast.While)):
            return p
        p = parent_of(p)
    return None


def _branch_chain(node: ast.AST, stop_at) -> Dict[int, str]:
    """{id(If): arm} for every enclosing ``if``; two consumers sharing an
    If on different arms are mutually exclusive, not reuse."""
    chain: Dict[int, str] = {}
    cur, p = node, parent_of(node)
    while p is not None and p is not stop_at:
        if isinstance(p, ast.If):
            if cur in p.body:
                chain[id(p)] = "body"
            elif cur in p.orelse:
                chain[id(p)] = "orelse"
        cur, p = p, parent_of(p)
    return chain


def _exclusive(a: Dict[int, str], b: Dict[int, str]) -> bool:
    return any(a[k] != b[k] for k in a.keys() & b.keys())


def _loop_varying_names(loop: ast.AST) -> Set[str]:
    varying: Set[str] = set()
    if isinstance(loop, ast.For):
        varying.update(_names_in(loop.target))
    for stmt in loop.body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
                varying.add(node.id)
    return varying


def check(tree: ast.AST, lines: Sequence[str], path: str) -> List[Finding]:
    findings: List[Finding] = []
    roots, bare = _random_roots(tree)
    # consumers grouped by nearest enclosing function (None = module level)
    by_scope: Dict[Optional[ast.AST],
                   List[Tuple[ast.Call, str, ast.AST]]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        cname = _consumer_name(node, roots, bare)
        if cname is None:
            continue
        key = _key_expr(node)
        if key is None or (isinstance(key, ast.Constant)
                           and not isinstance(key.value, str)):
            continue
        by_scope.setdefault(_enclosing_fn(node), []).append(
            (node, cname, key))

    for scope, consumers in by_scope.items():
        consumers.sort(key=lambda t: (t[0].lineno, t[0].col_offset))
        seen: Dict[str, List[ast.Call]] = {}
        for node, cname, key in consumers:
            sig = ast.unparse(key)
            chain = _branch_chain(node, scope)
            prior = [n for n in seen.get(sig, ())
                     if not _exclusive(chain, _branch_chain(n, scope))]
            if prior:
                findings.append(Finding(
                    RULE, path, node.lineno, node.col_offset,
                    f"key `{sig}` already consumed at line "
                    f"{prior[0].lineno}; split it (`jax.random.split`) "
                    f"instead of reusing"))
            seen.setdefault(sig, []).append(node)
            loop = _enclosing_loop(node, scope)
            if loop is not None:
                varying = _loop_varying_names(loop)
                if not (_names_in(key) & varying):
                    findings.append(Finding(
                        RULE, path, node.lineno, node.col_offset,
                        f"`{cname}` consumes key `{sig}` every loop "
                        f"iteration without re-splitting; samples are "
                        f"identical across iterations"))
    return findings
