"""doc-hygiene rule: the decision stack must stay documented.

``repro.core`` is the repo's public surface — the modules the README's
paper->module map points at. A module landing there without a module
docstring is invisible to that map; a public entry point without one
forces the next reader back to the call sites to recover units and
shapes. The rule keeps the documentation layer from rotting the way the
pre-README repo did (baseline stays empty: new findings fail CI).

Detected, only for files under a ``core/`` package directory:

  * missing or empty module docstring;
  * a public (non-underscore) module-level function or class whose body
    has no docstring — methods are exempt (the class docstring carries
    the contract), as are trivial defs (single-statement bodies such as
    property passthroughs and aliases).
"""
from __future__ import annotations

import ast
from typing import List

from tools.splint.engine import Finding

RULE = "doc-hygiene"


def _in_scope(path: str) -> bool:
    parts = path.replace("\\", "/").split("/")
    return "core" in parts


def _has_docstring(node) -> bool:
    doc = ast.get_docstring(node)
    return bool(doc and doc.strip())


def _trivial(node) -> bool:
    """Single-statement bodies (aliases, passthroughs) need no docstring."""
    return len(node.body) <= 1


def check(tree: ast.AST, lines, path: str) -> List[Finding]:
    if not _in_scope(path):
        return []
    findings: List[Finding] = []
    if not _has_docstring(tree):
        findings.append(Finding(
            RULE, path, 1, 0,
            "module has no docstring — core/ modules are the repo's "
            "public surface and must state what they model"))
    for node in ast.iter_child_nodes(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
            continue
        if node.name.startswith("_") or _has_docstring(node) \
                or _trivial(node):
            continue
        kind = "class" if isinstance(node, ast.ClassDef) else "function"
        findings.append(Finding(
            RULE, path, node.lineno, node.col_offset,
            f"public {kind} '{node.name}' has no docstring — state its "
            "contract (units for _s/_hz/_j values, array shapes)"))
    return findings
