"""splint engine: file scanning, pragma suppression, baseline, reporting.

The engine is deliberately pure-stdlib (``ast`` + ``json``) so the CI
static-analysis job never needs JAX installed — splint reasons about the
source, it does not import it.

Suppression layers, innermost first:

  1. ``# splint: ignore[rule-a,rule-b]`` — same line as the finding, or on
     a standalone comment line directly above it. ``# splint: ignore``
     (no bracket) suppresses every rule on that line.
  2. ``# splint: ignore-file[rule]`` anywhere in the file — suppresses the
     rule for the whole file.
  3. ``tools/splint/baseline.json`` — fingerprint counts of accepted
     pre-existing findings; only findings *beyond* the baselined count
     fail the run (the ratchet: the baseline may shrink, never grow).

Fingerprints are line-number-free (``path::rule::message``) so unrelated
edits above a baselined finding do not resurrect it.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

BASELINE_SCHEMA = "splint-baseline/v1"
REPORT_SCHEMA = "splint-report/v1"

RULES = (
    "trace-safety",   # host syncs / Python control flow on traced values
    "jit-hygiene",    # recompilation triggers, import-time jnp compute
    "pallas-block",   # BlockSpec arity, grid divisibility, accumulator init
    "unit-suffix",    # arithmetic mixing incompatible unit-suffixed names
    "prng-reuse",     # jax.random keys consumed more than once / in loops
    "dtype-promo",    # strong-typed scalars widening f32/bf16 hot paths
    "fault-hygiene",  # swallowed exceptions, unsuffixed timeout/deadline
    "doc-hygiene",    # core/ modules + public entry points need docstrings
    "parse-error",    # file does not parse (always reported)
)


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    @property
    def fingerprint(self) -> str:
        return f"{self.path}::{self.rule}::{self.message}"

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def to_dict(self) -> Dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "fingerprint": self.fingerprint}


# ---------------------------------------------------------------------------
# AST helpers shared by the detectors
# ---------------------------------------------------------------------------


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def call_name(node: ast.Call) -> Optional[str]:
    return dotted(node.func)


def add_parents(tree: ast.AST) -> None:
    """Attach ``.splint_parent`` links (detectors climb them for context)."""
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            child.splint_parent = parent  # type: ignore[attr-defined]


def parent_of(node: ast.AST) -> Optional[ast.AST]:
    return getattr(node, "splint_parent", None)


def const_str_tuple(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """A literal str or tuple/list of str constants, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, str):
                out.append(el.value)
            else:
                return None
        return tuple(out)
    return None


def const_int_tuple(node: ast.AST) -> Optional[Tuple[int, ...]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, int):
                out.append(el.value)
            else:
                return None
        return tuple(out)
    return None


# ---------------------------------------------------------------------------
# Pragmas
# ---------------------------------------------------------------------------

_PRAGMA = re.compile(r"#\s*splint:\s*ignore(?:\[([a-z0-9_,\s\-]+)\])?",
                     re.IGNORECASE)
_FILE_PRAGMA = re.compile(r"#\s*splint:\s*ignore-file(?:\[([a-z0-9_,\s\-]+)\])?",
                          re.IGNORECASE)


class Pragmas:
    """Per-file suppression map parsed from comments."""

    def __init__(self, lines: Sequence[str]):
        self.line_rules: Dict[int, Optional[set]] = {}  # None = all rules
        self.file_rules: Optional[set] = set()          # None = all rules
        self._file_all = False
        for i, text in enumerate(lines, start=1):
            m = _FILE_PRAGMA.search(text)
            if m:
                if m.group(1) is None:
                    self._file_all = True
                else:
                    self.file_rules.update(
                        r.strip() for r in m.group(1).split(",") if r.strip())
                continue
            m = _PRAGMA.search(text)
            if m:
                rules = (None if m.group(1) is None else
                         {r.strip() for r in m.group(1).split(",")
                          if r.strip()})
                targets = [i]
                # a standalone comment line suppresses the next line too
                if text.lstrip().startswith("#"):
                    targets.append(i + 1)
                for t in targets:
                    if rules is None or self.line_rules.get(t, set()) is None:
                        self.line_rules[t] = None
                    else:
                        cur = self.line_rules.setdefault(t, set())
                        cur.update(rules)

    def suppresses(self, finding: Finding) -> bool:
        if finding.rule == "parse-error":
            return False
        if self._file_all or finding.rule in (self.file_rules or ()):
            return True
        if finding.line in self.line_rules:
            rules = self.line_rules[finding.line]
            return rules is None or finding.rule in rules
        return False


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------


def load_baseline(path: Optional[Path]) -> Dict[str, int]:
    if path is None or not path.exists():
        return {}
    data = json.loads(path.read_text())
    if data.get("schema") != BASELINE_SCHEMA:
        raise ValueError(f"{path}: not a splint baseline "
                         f"(schema={data.get('schema')!r})")
    return dict(data.get("findings", {}))


def write_baseline(path: Path, findings: Iterable[Finding]) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for f in findings:
        counts[f.fingerprint] = counts.get(f.fingerprint, 0) + 1
    payload = {"schema": BASELINE_SCHEMA,
               "findings": dict(sorted(counts.items()))}
    path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    return counts


def split_new(findings: Sequence[Finding],
              baseline: Dict[str, int]) -> Tuple[List[Finding], List[Finding]]:
    """Partition into (new, baselined) honoring per-fingerprint counts."""
    budget = dict(baseline)
    new, old = [], []
    for f in findings:
        if budget.get(f.fingerprint, 0) > 0:
            budget[f.fingerprint] -= 1
            old.append(f)
        else:
            new.append(f)
    return new, old


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def iter_py_files(paths: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    # never lint vendored stubs or splint itself scanning its own fixtures
    return [p for p in out if "_stubs" not in p.parts
            and "__pycache__" not in p.parts]


def default_checkers():
    from tools.splint import (doc_rules, dtype_rules, fault_rules,
                              jit_hygiene, pallas_rules, prng_rules,
                              trace_safety, units)
    return [trace_safety.check, jit_hygiene.check, pallas_rules.check,
            units.check, prng_rules.check, dtype_rules.check,
            fault_rules.check, doc_rules.check]


@dataclasses.dataclass
class ScanResult:
    findings: List[Finding]          # active (unsuppressed) findings
    suppressed: List[Finding]        # pragma-suppressed
    files_scanned: int


def scan_source(src: str, path: str, checkers=None) -> List[Finding]:
    """All findings for one source blob (no pragma filtering) — the unit
    of testing for the detectors."""
    checkers = checkers if checkers is not None else default_checkers()
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding("parse-error", path, e.lineno or 0, e.offset or 0,
                        f"syntax error: {e.msg}")]
    add_parents(tree)
    lines = src.splitlines()
    findings: List[Finding] = []
    for check in checkers:
        findings.extend(check(tree, lines, path))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def scan_files(paths: Sequence[str], checkers=None) -> ScanResult:
    files = iter_py_files(paths)
    active: List[Finding] = []
    suppressed: List[Finding] = []
    for fp in files:
        src = fp.read_text()
        rel = fp.as_posix()
        found = scan_source(src, rel, checkers)
        pragmas = Pragmas(src.splitlines())
        for f in found:
            (suppressed if pragmas.suppresses(f) else active).append(f)
    return ScanResult(findings=active, suppressed=suppressed,
                      files_scanned=len(files))


def report_dict(result: ScanResult, new: Sequence[Finding],
                baselined: Sequence[Finding]) -> Dict:
    return {
        "schema": REPORT_SCHEMA,
        "files_scanned": result.files_scanned,
        "counts": {"new": len(new), "baselined": len(baselined),
                   "suppressed": len(result.suppressed)},
        "new": [f.to_dict() for f in new],
        "baselined": [f.to_dict() for f in baselined],
        "suppressed": [f.to_dict() for f in result.suppressed],
    }
