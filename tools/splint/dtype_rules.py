"""dtype-promo rule: strong-typed scalars widening f32/bf16 hot paths.

Python float literals are *weak*-typed in JAX — ``x * 0.5`` keeps a bf16
array bf16 — so those are fine and never flagged.  What silently widens
is a **strong**-typed NumPy scalar or 0-d array:

* ``np.float64(x) * arr`` / ``np.float32(x) + bf16_arr`` — NumPy scalar
  types carry a committed dtype that wins the promotion, upcasting a
  bf16 kernel input to f32 (or f32 to f64 where x64 is enabled);
* ``jnp.array(0.5) * arr`` / ``np.array(0.5) + arr`` without an explicit
  ``dtype=`` — the scalar commits to float32/float64 and promotes.

The fix is a plain Python literal, or an explicit ``dtype=`` /
``.astype`` matching the array being touched.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence

from tools.splint.engine import Finding, call_name

RULE = "dtype-promo"

_STRONG_SCALAR_CALLS = {
    "np.float64", "np.float32", "np.float16", "numpy.float64",
    "numpy.float32", "numpy.float16",
}
_ARRAY_CALLS = {"np.array", "numpy.array", "jnp.array", "jax.numpy.array"}


def _strong_operand(node: ast.AST) -> Optional[str]:
    """Describe node if it is a strong-typed scalar expression."""
    if not isinstance(node, ast.Call):
        return None
    name = call_name(node)
    if name in _STRONG_SCALAR_CALLS:
        return f"`{name}(...)` (strong-typed NumPy scalar)"
    if name in _ARRAY_CALLS and node.args \
            and isinstance(node.args[0], ast.Constant) \
            and isinstance(node.args[0].value, float) \
            and not any(kw.arg == "dtype" for kw in node.keywords):
        return f"`{name}({node.args[0].value})` without dtype="
    return None


def check(tree: ast.AST, lines: Sequence[str], path: str) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.BinOp):
            continue
        for side in (node.left, node.right):
            desc = _strong_operand(side)
            if desc:
                findings.append(Finding(
                    RULE, path, side.lineno, side.col_offset,
                    f"{desc} in arithmetic promotes f32/bf16 arrays; use a "
                    f"Python float literal or an explicit dtype"))
    return findings
