"""fault-hygiene rule: failure paths that silently swallow or mis-scale.

The churn-tolerance layer (``repro.core.faults``) only degrades gracefully
if (a) exception handlers never eat errors they cannot handle and (b) every
timeout/deadline constant carries an explicit time-unit suffix — a bare
``timeout = 30`` next to a ``deadline_ms`` is exactly the class of bug that
turns a 30 s retry budget into a 30 ms one.

Detected:

  * bare ``except:`` — swallows ``SystemExit``/``KeyboardInterrupt`` and
    makes injected-fault tests pass vacuously;
  * ``except Exception:`` / ``except BaseException:`` whose body is only
    ``pass`` / ``...`` — a failure path with no accounting at all;
  * a name containing the token ``timeout`` or ``deadline`` bound to a
    numeric literal while carrying no unit suffix the registry in
    :mod:`tools.splint.units` recognizes (assignments, annotated
    assignments, function-argument defaults, and call keywords).

``timeout_s = 30.0`` and ``deadline=None`` are both fine; ``timeout = 30``
is not.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence

from tools.splint.engine import Finding, dotted
from tools.splint.units import dimension_of

RULE = "fault-hygiene"

_TOKENS = {"timeout", "deadline"}
_BROAD = {"Exception", "BaseException", "builtins.Exception",
          "builtins.BaseException"}


def _is_numeric_literal(node: Optional[ast.AST]) -> bool:
    return (isinstance(node, ast.Constant)
            and isinstance(node.value, (int, float))
            and not isinstance(node.value, bool))


def _unsuffixed_fault_name(name: str) -> bool:
    toks = name.lower().rstrip("_").split("_")
    return bool(_TOKENS & set(toks)) and dimension_of(name) is None


def _pass_only(body: Sequence[ast.stmt]) -> bool:
    for stmt in body:
        if isinstance(stmt, ast.Pass):
            continue
        if (isinstance(stmt, ast.Expr)
                and isinstance(stmt.value, ast.Constant)
                and stmt.value.value is ...):
            continue
        return False
    return True


def check(tree: ast.AST, lines: Sequence[str], path: str) -> List[Finding]:
    findings: List[Finding] = []

    def flag(node: ast.AST, message: str) -> None:
        findings.append(Finding(RULE, path, node.lineno, node.col_offset,
                                message))

    def flag_name(node: ast.AST, name: str, where: str) -> None:
        flag(node, f"{where} `{name}` is a numeric literal without a unit "
                   f"suffix (use `{name}_s` or another registry suffix)")

    for node in ast.walk(tree):
        if isinstance(node, ast.ExceptHandler):
            if node.type is None:
                flag(node, "bare `except:` swallows SystemExit/"
                           "KeyboardInterrupt; catch specific exceptions")
            elif dotted(node.type) in _BROAD and _pass_only(node.body):
                flag(node, f"`except {dotted(node.type)}:` with a pass-only "
                           f"body hides failures; log, re-raise, or narrow "
                           f"the exception type")
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) \
                        and _unsuffixed_fault_name(tgt.id) \
                        and _is_numeric_literal(node.value):
                    flag_name(node, tgt.id, "assignment to")
        elif isinstance(node, ast.AnnAssign):
            if isinstance(node.target, ast.Name) \
                    and _unsuffixed_fault_name(node.target.id) \
                    and _is_numeric_literal(node.value):
                flag_name(node, node.target.id, "assignment to")
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = node.args
            positional = a.posonlyargs + a.args
            defaults = [None] * (len(positional) - len(a.defaults)) \
                + list(a.defaults)
            pairs = list(zip(positional, defaults)) \
                + list(zip(a.kwonlyargs, a.kw_defaults))
            for arg, default in pairs:
                if _unsuffixed_fault_name(arg.arg) \
                        and _is_numeric_literal(default):
                    flag_name(arg, arg.arg, "default for parameter")
        elif isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg and _unsuffixed_fault_name(kw.arg) \
                        and _is_numeric_literal(kw.value):
                    flag_name(kw.value, kw.arg, "keyword argument")
    return findings
