"""pallas-block rule: structural constraints on ``pl.pallas_call`` sites.

Checked per call site (kernel resolution is purely syntactic — splint
never imports JAX):

* **index-map arity** — every ``pl.BlockSpec`` index-map lambda must take
  ``grid_rank + num_scalar_prefetch`` arguments (scalar-prefetch refs are
  appended to the grid indices by ``PrefetchScalarGridSpec``).
* **kernel signature** — the kernel's positional parameter count must be
  ``prefetch + len(in_specs) + n_out + len(scratch_shapes)``; a silent
  off-by-one here binds a scratch ref to an output slot.
* **grid divisibility** — a ``X // D`` feeding the grid needs a matching
  ``% D`` in the same function (the pad-to-multiple idiom ``(-s) % D`` or
  an assert); otherwise ragged tails are silently dropped.
* **accumulator init** — a ``*_ref`` that is both read and written via
  subscript (carried across sequential grid steps in VMEM scratch) must
  be stored somewhere under a ``@pl.when(<idx> == 0)`` guard, or step 0
  reads garbage from the previous grid cell's leftovers.
* **tile alignment** — literal block-shape trailing dims that are >= 8
  but not lane/sublane aligned (last % 128, second-minor % 8).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Tuple

from tools.splint.engine import Finding, call_name, dotted, parent_of

RULE = "pallas-block"

_PALLAS_CALL = {"pl.pallas_call", "pallas_call"}
_PARTIAL = {"functools.partial", "partial"}


def _enclosing_function(node: ast.AST):
    p = parent_of(node)
    while p is not None and not isinstance(
            p, (ast.FunctionDef, ast.AsyncFunctionDef)):
        p = parent_of(p)
    return p


def _assignment_map(fn) -> Dict[str, ast.AST]:
    """name -> last assigned value expr inside ``fn`` (tuple unpack of
    matching arity handled element-wise)."""
    out: Dict[str, ast.AST] = {}
    if fn is None:
        return out
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        tgt = node.targets[0]
        if isinstance(tgt, ast.Name):
            out[tgt.id] = node.value
        elif (isinstance(tgt, ast.Tuple)
              and isinstance(node.value, ast.Tuple)
              and len(tgt.elts) == len(node.value.elts)):
            for t, v in zip(tgt.elts, node.value.elts, strict=True):
                if isinstance(t, ast.Name):
                    out[t.id] = v
    return out


def _resolve(node: Optional[ast.AST], env: Dict[str, ast.AST],
             depth: int = 4) -> Optional[ast.AST]:
    while depth and isinstance(node, ast.Name) and node.id in env:
        node = env[node.id]
        depth -= 1
    return node


def _const_int(node: Optional[ast.AST]) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    return None


def _seq_elts(node: Optional[ast.AST]) -> Optional[List[ast.AST]]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return list(node.elts)
    return None


def _kwarg(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _module_functions(tree: ast.AST) -> Dict[str, ast.FunctionDef]:
    out: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[node.name] = node
    return out


def _resolve_kernel(node: Optional[ast.AST], env: Dict[str, ast.AST],
                    fns: Dict[str, ast.FunctionDef]):
    node = _resolve(node, env)
    if isinstance(node, ast.Call) and call_name(node) in _PARTIAL \
            and node.args:
        node = _resolve(node.args[0], env)
    if isinstance(node, ast.Name):
        return fns.get(node.id)
    return None


def _block_specs(node: Optional[ast.AST], env: Dict[str, ast.AST]
                 ) -> Tuple[Optional[int], List[ast.Call]]:
    """(count, BlockSpec call nodes) for an in_specs/out_specs value."""
    node = _resolve(node, env)
    elts = _seq_elts(node)
    if elts is None:
        if isinstance(node, ast.Call):
            elts = [node]
        else:
            return None, []
    specs = [e for e in elts
             if isinstance(e, ast.Call)
             and (call_name(e) or "").endswith("BlockSpec")]
    return len(elts), specs


def _check_floordiv_guards(grid_elts: List[ast.AST], env: Dict[str, ast.AST],
                           fn, path: str, findings: List[Finding]) -> None:
    if fn is None:
        return
    mods = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mod):
            mods.add(ast.unparse(node.right))
    exprs: List[ast.AST] = []
    for e in grid_elts:
        exprs.append(e)
        r = _resolve(e, env)
        if r is not e and r is not None:
            exprs.append(r)
    for expr in exprs:
        for node in ast.walk(expr):
            if isinstance(node, ast.BinOp) \
                    and isinstance(node.op, ast.FloorDiv):
                divisor = ast.unparse(node.right)
                if divisor not in mods:
                    findings.append(Finding(
                        RULE, path, node.lineno, node.col_offset,
                        f"grid dimension `{ast.unparse(node)}` floor-divides "
                        f"by `{divisor}` with no `% {divisor}` pad/assert in "
                        f"scope; ragged tail elements are silently dropped"))


def _check_tile_alignment(spec: ast.Call, env: Dict[str, ast.AST],
                          path: str, findings: List[Finding]) -> None:
    shape = _resolve(spec.args[0] if spec.args
                     else _kwarg(spec, "block_shape"), env)
    elts = _seq_elts(shape)
    if not elts:
        return
    last = _const_int(_resolve(elts[-1], env))
    if last is not None and last >= 8 and last % 128 != 0:
        findings.append(Finding(
            RULE, path, spec.lineno, spec.col_offset,
            f"block_shape last dim {last} is not lane-aligned "
            f"(expected a multiple of 128)"))
    if len(elts) >= 2:
        second = _const_int(_resolve(elts[-2], env))
        if second is not None and second >= 8 and second % 8 != 0:
            findings.append(Finding(
                RULE, path, spec.lineno, spec.col_offset,
                f"block_shape second-minor dim {second} is not "
                f"sublane-aligned (expected a multiple of 8)"))


# -- accumulator-init analysis ----------------------------------------------


def _is_when_zero_guard(fn: ast.FunctionDef) -> bool:
    """True for ``@pl.when(<expr> == 0)``-decorated defs."""
    for dec in fn.decorator_list:
        if isinstance(dec, ast.Call) \
                and (call_name(dec) or "").endswith("when") and dec.args:
            test = dec.args[0]
            if isinstance(test, ast.Compare) and len(test.ops) == 1 \
                    and isinstance(test.ops[0], ast.Eq):
                for side in (test.left, test.comparators[0]):
                    if isinstance(side, ast.Constant) and side.value == 0:
                        return True
    return False


def _check_accumulator_init(kernel: ast.FunctionDef, path: str,
                            findings: List[Finding]) -> None:
    refs = {a.arg for a in (kernel.args.posonlyargs + kernel.args.args)
            if a.arg.endswith("_ref")}
    if not refs:
        return
    reads, writes, guarded_writes = set(), set(), set()
    for node in ast.walk(kernel):
        if not (isinstance(node, ast.Subscript)
                and isinstance(node.value, ast.Name)
                and node.value.id in refs):
            continue
        name = node.value.id
        if isinstance(node.ctx, ast.Load):
            reads.add(name)
        else:                       # Store / AugStore target
            writes.add(name)
            if isinstance(parent_of(node), ast.AugAssign):
                reads.add(name)     # += reads the previous grid step's value
            p = parent_of(node)
            while p is not kernel and p is not None:
                if isinstance(p, ast.FunctionDef) and _is_when_zero_guard(p):
                    guarded_writes.add(name)
                    break
                p = parent_of(p)
    for name in sorted((reads & writes) - guarded_writes):
        findings.append(Finding(
            RULE, path, kernel.lineno, kernel.col_offset,
            f"ref `{name}` in kernel `{kernel.name}` is carried across grid "
            f"steps (read and written) but never initialized under a "
            f"`pl.when(<idx> == 0)` guard; step 0 reads stale VMEM"))


def check(tree: ast.AST, lines: Sequence[str], path: str) -> List[Finding]:
    findings: List[Finding] = []
    fns = _module_functions(tree)
    checked_kernels = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and call_name(node) in _PALLAS_CALL):
            continue
        fn = _enclosing_function(node)
        env = _assignment_map(fn)

        grid_v = _kwarg(node, "grid")
        in_specs_v = _kwarg(node, "in_specs")
        out_specs_v = _kwarg(node, "out_specs")
        out_shape_v = _kwarg(node, "out_shape")
        scratch_v = _kwarg(node, "scratch_shapes")
        prefetch = 0

        grid_spec = _resolve(_kwarg(node, "grid_spec"), env)
        if isinstance(grid_spec, ast.Call):
            grid_v = _kwarg(grid_spec, "grid") or grid_v
            in_specs_v = _kwarg(grid_spec, "in_specs") or in_specs_v
            out_specs_v = _kwarg(grid_spec, "out_specs") or out_specs_v
            scratch_v = _kwarg(grid_spec, "scratch_shapes") or scratch_v
            prefetch = _const_int(
                _kwarg(grid_spec, "num_scalar_prefetch")) or 0

        grid_elts = _seq_elts(_resolve(grid_v, env))
        rank = len(grid_elts) if grid_elts is not None else None
        if grid_elts:
            _check_floordiv_guards(grid_elts, env, fn, path, findings)

        n_in, in_specs = _block_specs(in_specs_v, env)
        n_out_specs, out_specs = _block_specs(out_specs_v, env)
        for spec in in_specs + out_specs:
            _check_tile_alignment(spec, env, path, findings)
            index_map = (spec.args[1] if len(spec.args) > 1
                         else _kwarg(spec, "index_map"))
            if rank is not None and isinstance(index_map, ast.Lambda):
                arity = len(index_map.args.posonlyargs
                            + index_map.args.args)
                want = rank + prefetch
                if arity != want:
                    findings.append(Finding(
                        RULE, path, index_map.lineno, index_map.col_offset,
                        f"BlockSpec index map takes {arity} args but grid "
                        f"rank {rank} + {prefetch} scalar-prefetch "
                        f"requires {want}"))

        n_out = None
        out_shape = _resolve(out_shape_v, env)
        shape_elts = _seq_elts(out_shape)
        if shape_elts is not None:
            n_out = len(shape_elts)
        elif isinstance(out_shape, ast.Call):
            n_out = 1
        elif n_out_specs is not None:
            n_out = n_out_specs

        n_scratch = 0
        scratch_elts = _seq_elts(_resolve(scratch_v, env))
        if scratch_elts is not None:
            n_scratch = len(scratch_elts)
        elif scratch_v is not None:
            n_scratch = None        # present but unresolvable

        kernel = _resolve_kernel(node.args[0] if node.args else None,
                                 env, fns)
        if kernel is not None and None not in (n_in, n_out, n_scratch):
            n_params = len(kernel.args.posonlyargs + kernel.args.args)
            want = prefetch + n_in + n_out + n_scratch
            if n_params != want:
                findings.append(Finding(
                    RULE, path, node.lineno, node.col_offset,
                    f"kernel `{kernel.name}` takes {n_params} positional "
                    f"refs but pallas_call provides {want} "
                    f"({prefetch} prefetch + {n_in} in + {n_out} out + "
                    f"{n_scratch} scratch)"))
        if kernel is not None and kernel.name not in checked_kernels:
            checked_kernels.add(kernel.name)
            _check_accumulator_init(kernel, path, findings)
    return findings
