"""splint — repo-native static analysis for JAX trace-safety, Pallas
kernel constraints, and cost-model unit consistency.

Run as ``python -m tools.splint src benchmarks tests``; see
``tools/splint/README.md`` for the rule catalog and baseline workflow.
"""
from tools.splint.engine import (Finding, RULES, load_baseline,  # noqa: F401
                                 scan_files, scan_source, split_new,
                                 write_baseline)
from tools.splint.units import (ALIAS_SUFFIXES, UNIT_SUFFIXES,  # noqa: F401
                                check_key_units, dimension_of,
                                key_dimensions)
