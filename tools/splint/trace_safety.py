"""trace-safety rule: host syncs and Python control flow on traced values.

Two sub-analyses share the rule id:

**(a) taint inside jit/vmap/shard_map-decorated functions.**  Parameters
(minus ``static_argnames``/``static_argnums``) are traced; taint
propagates through assignments.  Flagged on tainted values: Python
``if``/``while``/``assert`` tests and ``for`` iterators (tracer leaks
into Python control flow -> ConcretizationTypeError or silent
specialization), ``float()``/``int()``/``bool()`` casts, ``.item()``/
``.tolist()``, and ``np.*`` calls (host round-trip under trace).
Accesses through ``.shape``/``.ndim``/``.dtype``/``.size`` are static
under tracing and never count.

**(b) per-iteration host syncs in hot loops** (any function, jitted or
not): ``.item()``/``.tolist()``, ``jax.block_until_ready``/
``jax.device_get`` inside a ``for``/``while`` body, and ``float()``/
``int()``/``np.asarray()``/``np.array()`` of a name freshly produced by a
call in the same loop body — the "silently sync every iteration" pattern
that serializes a fleet sweep.  Timing harnesses that sync on purpose
carry a ``# splint: ignore[trace-safety]`` pragma.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence, Set, Tuple

from tools.splint.engine import (Finding, call_name, const_int_tuple,
                                 const_str_tuple, dotted, parent_of)

RULE = "trace-safety"

_JIT_NAMES = {"jax.jit", "jit", "jax.vmap", "vmap", "jax.pmap", "pmap",
              "shard_map", "jax.experimental.shard_map.shard_map",
              "jax.shard_map"}
_PARTIAL_NAMES = {"functools.partial", "partial"}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
_SYNC_METHODS = {"item", "tolist"}
_HOST_CASTS = {"float", "int", "bool"}
_NP_ROOTS = ("np.", "numpy.")
_LOOP_SYNC_CALLS = {"jax.block_until_ready", "jax.device_get"}


def jit_static_names(fn: ast.FunctionDef) -> Optional[Set[str]]:
    """If ``fn`` is jit/vmap/shard_map-decorated, the set of static param
    names; None if it is not jitted."""
    params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    for dec in fn.decorator_list:
        name = dotted(dec)
        if name in _JIT_NAMES:
            return set()
        if isinstance(dec, ast.Call):
            fname = call_name(dec)
            target_kw = None
            if fname in _JIT_NAMES:
                target_kw = dec.keywords
            elif (fname in _PARTIAL_NAMES and dec.args
                  and dotted(dec.args[0]) in _JIT_NAMES):
                target_kw = dec.keywords
            if target_kw is not None:
                static: Set[str] = set()
                for kw in target_kw:
                    if kw.arg == "static_argnames":
                        static.update(const_str_tuple(kw.value) or ())
                    elif kw.arg == "static_argnums":
                        for i in const_int_tuple(kw.value) or ():
                            if 0 <= i < len(params):
                                static.add(params[i])
                return static
    return None


def _tainted_value_names(expr: ast.AST, tainted: Set[str]) -> Set[str]:
    """Tainted names used *as values* in expr — occurrences reached only
    through static attributes (.shape/.ndim/...) don't count."""
    hits: Set[str] = set()
    for node in ast.walk(expr):
        if not (isinstance(node, ast.Name) and node.id in tainted):
            continue
        p, cur = parent_of(node), node
        static_access = False
        while p is not None:
            if isinstance(p, ast.Attribute) and p.value is cur \
                    and p.attr in _STATIC_ATTRS:
                static_access = True
                break
            if isinstance(p, ast.Call) and p.func is cur:
                static_access = True       # calling a tainted callable: skip
                break
            if p is expr:
                break
            cur, p = p, parent_of(p)
        if not static_access:
            hits.add(node.id)
    return hits


def _assign_targets(node: ast.AST) -> List[str]:
    out: List[str] = []
    for t in ast.walk(node):
        if isinstance(t, ast.Name) and isinstance(t.ctx, ast.Store):
            out.append(t.id)
    return out


class _JittedBodyVisitor(ast.NodeVisitor):
    """Taint pass over one jitted function body."""

    def __init__(self, fn_name: str, tainted: Set[str], path: str,
                 findings: List[Finding]):
        self.fn = fn_name
        self.tainted = tainted
        self.path = path
        self.findings = findings

    def _flag(self, node, msg):
        self.findings.append(Finding(RULE, self.path, node.lineno,
                                     node.col_offset, msg))

    def _hits(self, expr) -> Set[str]:
        return _tainted_value_names(expr, self.tainted)

    # -- propagation ---------------------------------------------------------
    def visit_Assign(self, node):
        if self._hits(node.value):
            self.tainted.update(_assign_targets(node))
        self.generic_visit(node)

    def visit_AnnAssign(self, node):
        if node.value is not None and self._hits(node.value):
            self.tainted.update(_assign_targets(node.target))
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        if self._hits(node.value):
            self.tainted.update(_assign_targets(node.target))
        self.generic_visit(node)

    # -- control flow on traced values ---------------------------------------
    def visit_If(self, node):
        hits = self._hits(node.test)
        if hits:
            self._flag(node, f"Python `if` on traced value(s) "
                             f"{sorted(hits)} inside jitted `{self.fn}`; "
                             f"use jnp.where or lax.cond")
        self.generic_visit(node)

    def visit_While(self, node):
        hits = self._hits(node.test)
        if hits:
            self._flag(node, f"Python `while` on traced value(s) "
                             f"{sorted(hits)} inside jitted `{self.fn}`; "
                             f"use lax.while_loop")
        self.generic_visit(node)

    def visit_For(self, node):
        hits = self._hits(node.iter)
        if hits:
            self._flag(node, f"Python loop over traced value(s) "
                             f"{sorted(hits)} inside jitted `{self.fn}`; "
                             f"use lax.scan or lax.fori_loop")
        else:
            self.tainted.update(_assign_targets(node.target))
        self.generic_visit(node)

    def visit_Assert(self, node):
        hits = self._hits(node.test)
        if hits:
            self._flag(node, f"assert on traced value(s) {sorted(hits)} "
                             f"inside jitted `{self.fn}`; use "
                             f"checkify or a host-side validation")
        self.generic_visit(node)

    # -- host syncs ----------------------------------------------------------
    def visit_Call(self, node):
        name = call_name(node)
        if name in _HOST_CASTS and node.args \
                and self._hits(node.args[0]):
            self._flag(node, f"`{name}()` on traced value inside jitted "
                             f"`{self.fn}` forces a host sync "
                             f"(ConcretizationTypeError under jit)")
        elif name and name.startswith(_NP_ROOTS) and any(
                self._hits(a) for a in node.args):
            self._flag(node, f"`{name}` on traced value inside jitted "
                             f"`{self.fn}`; use the jnp equivalent")
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr in _SYNC_METHODS \
                and self._hits(node.func.value):
            self._flag(node, f"`.{node.func.attr}()` on traced value inside "
                             f"jitted `{self.fn}` forces a host sync")
        self.generic_visit(node)

    def visit_FunctionDef(self, node):
        # nested defs (pl.when closures etc.) trace with the outer scope
        self.generic_visit(node)


# ---------------------------------------------------------------------------
# (b) per-iteration host syncs in loops
# ---------------------------------------------------------------------------


def _call_assigned_names(loop_body: Sequence[ast.stmt]) -> Set[str]:
    """Names assigned from a call result anywhere inside the loop body."""
    out: Set[str] = set()
    for stmt in loop_body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                out.update(_assign_targets(node))
    return out


def _check_loops(tree: ast.AST, path: str, jitted: Set[ast.AST]
                 ) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.For, ast.While)):
            continue
        # loops *inside* jitted functions are covered by the taint pass
        p = parent_of(node)
        in_jitted = False
        while p is not None:
            if p in jitted:
                in_jitted = True
                break
            p = parent_of(p)
        if in_jitted:
            continue
        fresh = _call_assigned_names(node.body)
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            name = call_name(sub)
            if isinstance(sub.func, ast.Attribute) \
                    and sub.func.attr in _SYNC_METHODS:
                findings.append(Finding(
                    RULE, path, sub.lineno, sub.col_offset,
                    f"`.{sub.func.attr}()` inside a loop syncs the device "
                    f"every iteration; hoist or batch"))
            elif name in _LOOP_SYNC_CALLS:
                findings.append(Finding(
                    RULE, path, sub.lineno, sub.col_offset,
                    f"`{name}` inside a loop syncs every iteration; hoist "
                    f"out of the loop (or pragma if the sync is the point)"))
            elif name in {"float", "int"} and len(sub.args) == 1 \
                    and isinstance(sub.args[0], ast.Name) \
                    and sub.args[0].id in fresh:
                findings.append(Finding(
                    RULE, path, sub.lineno, sub.col_offset,
                    f"`{name}({sub.args[0].id})` syncs on a freshly computed "
                    f"device value every loop iteration; hoist the "
                    f"conversion out of the loop"))
            elif name in {"np.asarray", "np.array", "numpy.asarray",
                          "numpy.array"} and sub.args \
                    and isinstance(sub.args[0], ast.Name) \
                    and sub.args[0].id in fresh:
                findings.append(Finding(
                    RULE, path, sub.lineno, sub.col_offset,
                    f"`{name}({sub.args[0].id})` transfers a freshly "
                    f"computed device value every loop iteration; batch "
                    f"the transfer after the loop"))
    return findings


def check(tree: ast.AST, lines: Sequence[str], path: str) -> List[Finding]:
    findings: List[Finding] = []
    jitted: Set[ast.AST] = set()
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        static = jit_static_names(node)
        if static is None:
            continue
        jitted.add(node)
        params = [a.arg for a in (node.args.posonlyargs + node.args.args
                                  + node.args.kwonlyargs)]
        tainted = {p for p in params if p not in static} - {"self", "cls"}
        visitor = _JittedBodyVisitor(node.name, tainted, path, findings)
        for stmt in node.body:
            visitor.visit(stmt)
    findings.extend(_check_loops(tree, path, jitted))
    return findings
