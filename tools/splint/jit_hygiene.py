"""jit-hygiene rule: recompilation triggers and import-time device work.

Detected:

* ``jnp.*`` / ``jax.random.*`` / ``jax.numpy.*`` computation executed at
  module import time (module top level or class body, outside any
  function and outside ``if __name__ == "__main__":``).  Import-time jnp
  initializes the backend and bakes arrays into module state before any
  config (``jax.config.update``) can run; keep module constants in
  NumPy and convert at trace time.
* ``jax.jit(...)`` called inside a loop body — re-wrapping per iteration
  defeats the compile cache keyed on the wrapper object.
* ``static_argnames`` naming a parameter the jitted function doesn't
  have (silent: JAX only errors when the name is passed), and
  ``static_argnums`` out of range of the positional parameter list.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence

from tools.splint.engine import (Finding, call_name, const_int_tuple,
                                 const_str_tuple, dotted, parent_of)

RULE = "jit-hygiene"

_IMPORT_TIME_ROOTS = ("jnp.", "jax.numpy.", "jax.random.")
_JIT_CALLS = {"jax.jit", "jit"}
_PARTIAL_NAMES = {"functools.partial", "partial"}


def _is_main_guard(node: ast.If) -> bool:
    t = node.test
    return (isinstance(t, ast.Compare) and isinstance(t.left, ast.Name)
            and t.left.id == "__name__")


def _module_level_stmts(tree: ast.Module):
    """Statements executed at import time: module body and class bodies,
    recursing through top-level ``if``/``try`` but not into functions or
    the ``__main__`` guard."""
    stack = list(tree.body)
    while stack:
        stmt = stack.pop()
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if isinstance(stmt, ast.If):
            if _is_main_guard(stmt):
                continue
            stack.extend(stmt.body)
            stack.extend(stmt.orelse)
            continue
        if isinstance(stmt, ast.Try):
            stack.extend(stmt.body)
            for h in stmt.handlers:
                stack.extend(h.body)
            stack.extend(stmt.orelse)
            stack.extend(stmt.finalbody)
            continue
        if isinstance(stmt, ast.ClassDef):
            stack.extend(stmt.body)
            continue
        yield stmt


def _walk_skipping_defs(stmt: ast.stmt):
    """Walk a statement's expressions without descending into nested
    function bodies (those run at call time, not import time)."""
    stack = [stmt]
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                # defaults/decorators DO evaluate at import time
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    stack.extend(child.decorator_list)
                    stack.extend(child.args.defaults)
                    stack.extend(d for d in child.args.kw_defaults if d)
                continue
            stack.append(child)


def _fn_params(fn) -> List[str]:
    a = fn.args
    return [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]


def _check_static_args(call: ast.Call, path: str,
                       findings: List[Finding]) -> None:
    """Validate static_argnames/static_argnums when the jitted target is a
    plain function whose def is findable (decorator form handled via the
    decorated FunctionDef parent; call form via Name lookup is skipped —
    we only validate the decorator idiom, which is what the repo uses)."""
    fn = None
    p = parent_of(call)
    if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)) \
            and call in p.decorator_list:
        fn = p
    if fn is None:
        return
    params = _fn_params(fn)
    pos_params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            names = const_str_tuple(kw.value)
            for name in names or ():
                if name not in params:
                    findings.append(Finding(
                        RULE, path, call.lineno, call.col_offset,
                        f"static_argnames names `{name}` but `{fn.name}` "
                        f"has no such parameter (silently non-static)"))
        elif kw.arg == "static_argnums":
            nums = const_int_tuple(kw.value)
            for i in nums or ():
                if not (-len(pos_params) <= i < len(pos_params)):
                    findings.append(Finding(
                        RULE, path, call.lineno, call.col_offset,
                        f"static_argnums index {i} out of range for "
                        f"`{fn.name}` ({len(pos_params)} positional params)"))


def check(tree: ast.AST, lines: Sequence[str], path: str) -> List[Finding]:
    findings: List[Finding] = []

    # -- import-time jnp work ------------------------------------------------
    if isinstance(tree, ast.Module):
        for stmt in _module_level_stmts(tree):
            for node in _walk_skipping_defs(stmt):
                if not isinstance(node, ast.Call):
                    continue
                name = call_name(node)
                if name and name.startswith(_IMPORT_TIME_ROOTS):
                    findings.append(Finding(
                        RULE, path, node.lineno, node.col_offset,
                        f"`{name}` runs at module import time; build "
                        f"constants with numpy and convert at trace time"))

    # -- jax.jit in loops + static_arg validation ----------------------------
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        is_jit = name in _JIT_CALLS or (
            name in _PARTIAL_NAMES and node.args
            and dotted(node.args[0]) in _JIT_CALLS)
        if not is_jit:
            continue
        _check_static_args(node, path, findings)
        p = parent_of(node)
        while p is not None:
            if isinstance(p, (ast.For, ast.While)):
                findings.append(Finding(
                    RULE, path, node.lineno, node.col_offset,
                    "`jax.jit` called inside a loop creates a fresh "
                    "compile-cache entry per iteration; jit once outside"))
                break
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node in p.decorator_list:
                break
            p = parent_of(p)
    return findings
