"""unit-suffix rule: the repo's unit-suffix registry + the AST detector.

The cost model's numbers only mean anything if ``_s`` seconds never get
added to ``_joules`` or ``_bytes`` (Eqs. 9-12 mix all three families one
step apart).  The registry below is the single source of truth for what a
trailing ``_<token>`` means; it is also imported by
``benchmarks/check_regression.py`` to validate BENCH_*.json payload keys.

Dimension strings are deliberately scale-aware: ``_s`` and ``_us`` map to
*different* dimensions (``time[s]`` vs ``time[us]``) — adding seconds to
microseconds is exactly the class of bug this rule exists for.  Rates are
composed: ``flops_per_s`` has dimension ``compute/time[s]``.

Detected: ``+``/``-`` and comparisons where *both* operands are names (or
attributes/subscripts of names) whose suffixes resolve to different
dimensions.  Multiplication/division are unit-producing, not unit-mixing,
and are left alone.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence

from tools.splint.engine import Finding

RULE = "unit-suffix"

#: suffix token -> dimension. Scale variants are distinct dimensions on
#: purpose (mixing them is a bug even though they "measure the same thing").
UNIT_SUFFIXES: Dict[str, str] = {
    "s": "time[s]",
    "ms": "time[ms]",
    "us": "time[us]",
    "ns": "time[ns]",
    "joules": "energy[J]",
    "j": "energy[J]",
    "flops": "compute[flop]",
    "flop": "compute[flop]",
    "bytes": "data[byte]",
    "bits": "data[bit]",
    "hz": "frequency[Hz]",
    "ghz": "frequency[GHz]",
    "w": "power[W]",
    "watts": "power[W]",
    "db": "level[dB]",
    "dbm": "power[dBm]",
    "m": "length[m]",
}

#: near-miss spellings that should be normalized, never introduced
ALIAS_SUFFIXES: Dict[str, str] = {
    "sec": "s", "secs": "s", "second": "s", "seconds": "s",
    "msec": "ms", "msecs": "ms", "usec": "us", "micros": "us",
    "joule": "joules", "joul": "joules",
    "byte": "bytes", "bit": "bits",
    "hertz": "hz", "watt": "w",
    "millis": "ms", "nanos": "ns",
}

#: bare names (no underscore) that still carry a unit; single letters like
#: ``s``/``m``/``w`` are far too overloaded to count
_BARE_UNIT_NAMES = {"flops", "bytes", "bits", "joules", "seconds", "watts"}


def dimension_of(name: str) -> Optional[str]:
    """Dimension of a variable/attribute name, or None if unsuffixed.

    ``layer_s`` -> ``time[s]``; ``flops_per_s`` -> ``compute[flop]/time[s]``;
    ``d_model`` -> None. Trailing underscores (``bytes_``) are stripped.
    """
    name = name.rstrip("_")
    toks = name.split("_")
    if len(toks) >= 3 and toks[-2] == "per":
        num = UNIT_SUFFIXES.get(toks[-3])
        den = UNIT_SUFFIXES.get(toks[-1])
        if num and den:
            return f"{num}/{den}"
        if den:                      # e.g. decisions_per_s -> rate over time
            return f"count/{den}"
        return None
    if len(toks) >= 2:
        return UNIT_SUFFIXES.get(toks[-1])
    # bare name: only unambiguous multi-char unit words count
    if name in _BARE_UNIT_NAMES:
        return UNIT_SUFFIXES.get(name, UNIT_SUFFIXES.get(name.rstrip("s")))
    return None


def _expr_name_and_dim(node: ast.AST):
    """(display-name, dimension) for Name/Attribute/Subscript chains."""
    if isinstance(node, ast.Name):
        return node.id, dimension_of(node.id)
    if isinstance(node, ast.Attribute):
        return node.attr, dimension_of(node.attr)
    if isinstance(node, ast.Subscript):
        return _expr_name_and_dim(node.value)
    return None, None


def check(tree: ast.AST, lines: Sequence[str], path: str) -> List[Finding]:
    findings: List[Finding] = []

    def flag(node, a, da, b, db):
        findings.append(Finding(
            RULE, path, node.lineno, node.col_offset,
            f"unit mismatch: `{a}` [{da}] combined with `{b}` [{db}]"))

    for node in ast.walk(tree):
        if isinstance(node, ast.BinOp) and isinstance(node.op,
                                                      (ast.Add, ast.Sub)):
            a, da = _expr_name_and_dim(node.left)
            b, db = _expr_name_and_dim(node.right)
            if da and db and da != db:
                flag(node, a, da, b, db)
        elif isinstance(node, ast.Compare):
            operands = [node.left] + list(node.comparators)
            # adjacent operand pairs — deliberately unequal lengths
            for lhs, rhs in zip(operands, operands[1:], strict=False):
                a, da = _expr_name_and_dim(lhs)
                b, db = _expr_name_and_dim(rhs)
                if da and db and da != db:
                    flag(node, a, da, b, db)
    return findings


# ---------------------------------------------------------------------------
# Payload-key validation (imported by benchmarks/check_regression.py)
# ---------------------------------------------------------------------------


def key_dimensions(key: str) -> List[str]:
    """All unit dimensions a snake_case payload key mentions, with
    ``a_per_b`` rate groups collapsed to one dimension."""
    toks = key.rstrip("_").split("_")
    dims: List[str] = []
    i = 0
    while i < len(toks):
        if (i + 2 < len(toks) and toks[i + 1] == "per"
                and toks[i] in UNIT_SUFFIXES and toks[i + 2] in UNIT_SUFFIXES):
            dims.append(f"{UNIT_SUFFIXES[toks[i]]}/{UNIT_SUFFIXES[toks[i + 2]]}")
            i += 3
        elif toks[i] in UNIT_SUFFIXES:
            dims.append(UNIT_SUFFIXES[toks[i]])
            i += 1
        else:
            i += 1
    return dims


def check_key_units(keys: Sequence[str], *, context: str = "payload",
                    require: Optional[str] = None) -> List[str]:
    """Errors for payload keys with alias or inconsistent unit suffixes.

    ``require`` (a dimension string, e.g. ``"time[s]"``) additionally
    demands every key mention that dimension — the gates dict is wall
    seconds by contract, so a gate key without ``_s`` is a schema bug.
    """
    errors: List[str] = []
    for key in keys:
        toks = key.rstrip("_").split("_")
        for tok in toks:
            if tok in ALIAS_SUFFIXES:
                errors.append(
                    f"{context}: key {key!r} uses nonstandard unit token "
                    f"'{tok}' (use '{ALIAS_SUFFIXES[tok]}')")
        dims = key_dimensions(key)
        plain = [d for d in dims if "/" not in d]
        if len(set(plain)) > 1:
            errors.append(f"{context}: key {key!r} mixes unit suffixes "
                          f"{sorted(set(plain))}")
        if require and not dims:
            errors.append(f"{context}: key {key!r} carries no unit suffix "
                          f"(expected {require})")
        elif require and dims and require not in dims \
                and not any(d.startswith(require) or f"/{require}" in d
                            for d in dims):
            errors.append(f"{context}: key {key!r} has units {dims}, "
                          f"expected {require}")
    return errors
