"""End-to-end driver: the paper's full experiment — 5 heterogeneous Jetson
devices + 1 server, CARD vs the two baselines, real split LoRA fine-tuning
for a few hundred device-rounds, plus the Fig. 3 / Fig. 4 summaries.

    PYTHONPATH=src python examples/edge_finetune.py [--rounds 20] [--policy card]
"""
import argparse

import jax
import numpy as np

from repro.core.channel import WirelessChannel
from repro.core.hardware import EDGE_FLEET, SERVER_RTX4060TI, SimParams
from repro.core.protocol import SplitFineTuner
from repro.core.scheduler import simulate_fleet
from repro.data import make_fleet_datasets
from repro.launch.train import run_training
from repro.models import model as M
from repro.optim import adamw, warmup_cosine


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--rounds", type=int, default=20)
    p.add_argument("--policy", default="card",
                   choices=["card", "server_only", "device_only"])
    p.add_argument("--channel", default="normal",
                   choices=["good", "normal", "poor"])
    args = p.parse_args()

    print(f"== pre-train backbone ==")
    pre = run_training(arch="llama32-1b", steps=0, pretrain_steps=100,
                       batch=8, seq_len=64, log_every=0)
    cfg, frozen = pre["cfg"], pre["frozen"]

    print(f"== split fine-tuning: 5 devices, policy={args.policy}, "
          f"channel={args.channel}, {args.rounds} rounds ==")
    sim = SimParams(local_epochs=2, mini_batch=8, seq_len=64)
    lora = M.init_params(jax.random.PRNGKey(2), cfg)["lora"]
    total_steps = args.rounds * len(EDGE_FLEET) * sim.local_epochs
    from repro.configs.base import get_config as _gc
    ft = SplitFineTuner(
        cfg, frozen, lora, adamw(warmup_cosine(3e-3, 20, total_steps)),
        cost_cfg=_gc("llama32-1b"),
        devices=list(EDGE_FLEET), server=SERVER_RTX4060TI,
        channels=[WirelessChannel(args.channel, seed=11 * i)
                  for i in range(len(EDGE_FLEET))],
        datasets=make_fleet_datasets(cfg, len(EDGE_FLEET),
                                     vocab=cfg.vocab_size, seed=3),
        sim=sim, policy=args.policy)
    res = ft.run(args.rounds)

    losses = res.losses()
    print(f"loss: first5={np.mean(losses[:5]):.3f}  "
          f"last5={np.mean(losses[-5:]):.3f}")
    print(f"simulated: mean delay {res.mean_delay():.2f}s  "
          f"mean server energy {res.mean_energy():.1f}J")
    per_dev = {}
    for log in res.logs:
        per_dev.setdefault(log.device, []).append(log.cut)
    for dev, cuts in per_dev.items():
        print(f"  {dev}: cuts {sorted(set(cuts))} "
              f"(offload frac {np.mean(np.array(cuts) == 0):.2f})")

    print("== decision-level comparison (paper Fig. 4, full-size model) ==")
    from repro.configs.base import get_config
    full = get_config("llama32-1b")
    for policy in ("card", "server_only", "device_only"):
        log = simulate_fleet(full, policy=policy,
                             channel_state=args.channel, rounds=30)
        print(f"  {policy:12s} delay {log.mean_delay():8.2f}s   "
              f"energy {log.mean_energy():9.1f}J")


if __name__ == "__main__":
    main()
