"""Serving example: batched autoregressive decoding with KV/SSM caches for
every assigned architecture family (reduced configs, CPU).

Shows the serve path the decode_32k / long_500k dry-run shapes lower:
dense GQA full-cache, sliding-window ring buffer, Mamba2 constant state,
hybrid attn+SSM, MoE top-k routing, and an embeds-frontend (MusicGen stub).

    PYTHONPATH=src python examples/serve_multiarch.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.launch.serve import generate
from repro.models import model as M

ARCHS = ["qwen3-0.6b", "granite-moe-3b-a800m", "mamba2-370m", "hymba-1.5b",
         "qwen2-7b"]


def main() -> None:
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                    cfg.vocab_size)
        t0 = time.time()
        toks = generate(cfg, params["frozen"], params["lora"], prompt,
                        max_new=12, temperature=0.8,
                        key=jax.random.PRNGKey(2))
        dt = time.time() - t0
        print(f"{arch:24s} [{cfg.family:6s}] generated {toks.shape} "
              f"in {dt:5.1f}s  sample={toks[0, :6].tolist()}")

    # embeds-mode arch: frontend stub provides frame embeddings; decode then
    # feeds generated *tokens* through the decoder's own embedding table
    cfg = get_config("musicgen-large").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    cache = M.init_cache(cfg, 1, 16)
    frame = jax.random.normal(jax.random.PRNGKey(3), (1, 1, cfg.d_model),
                              jnp.float32) * 0.02
    logits, cache = M.decode_step(params["frozen"], params["lora"], cache,
                                  frame, jnp.int32(0), cfg)
    print(f"{'musicgen-large':24s} [audio ] one decode step from a frame "
          f"embedding -> logits {logits.shape}")


def continuous_batching_demo() -> None:
    """Multi-tenant continuous batching: one frozen backbone, a bank of
    fleet LoRA adapters gathered per-slot inside the jitted decode tick,
    chunked prefill, and channel-aware admission sharing the edge band
    with SL training."""
    import numpy as np
    from repro.serving import (AdapterBank, ChannelAdmissionController,
                               Request, ServingEngine)

    cfg = get_config("qwen3-0.6b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    bank = AdapterBank([M.init_params(jax.random.PRNGKey(s), cfg)["lora"]
                        for s in (0, 7, 13)])
    ctl = ChannelAdmissionController(bandwidth_hz=2e5,
                                     training_reserve_frac=0.5,
                                     token_rate_per_s=200.0, seed=0)
    eng = ServingEngine(cfg, params["frozen"], bank, slots=3, max_len=64,
                        prefill_chunk=4, admission=ctl)
    rng = np.random.default_rng(0)
    for i in range(6):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 4 + i,
                                               dtype=np.int32),
                           max_new=8, adapter_id=i % bank.n))
    stats = eng.run_until_drained()
    print(f"continuous batching: {stats['completed']} reqs x "
          f"{bank.n} adapters, {stats['tokens']} tokens in "
          f"{stats['ticks']} ticks + {stats['prefills']} prefill chunks "
          f"({stats['tokens_per_sec']:.1f} tok/s CPU, "
          f"ttft {stats['mean_ttft_s']:.2f}s)")
    adm = stats["admission"]
    for aid, t in adm["tenants"].items():
        print(f"  tenant adapter={aid}: {t['admitted']} admitted, "
              f"{t['blocked_attempts']} blocked attempts, "
              f"mean demand {t['mean_demand_hz'] / 1e3:.1f} kHz "
              f"of {adm['capacity_hz'] / 1e3:.0f} kHz serving capacity")


if __name__ == "__main__":
    main()
    continuous_batching_demo()
