"""Serving example: batched autoregressive decoding with KV/SSM caches for
every assigned architecture family (reduced configs, CPU).

Shows the serve path the decode_32k / long_500k dry-run shapes lower:
dense GQA full-cache, sliding-window ring buffer, Mamba2 constant state,
hybrid attn+SSM, MoE top-k routing, and an embeds-frontend (MusicGen stub).

    PYTHONPATH=src python examples/serve_multiarch.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.launch.serve import generate
from repro.models import model as M

ARCHS = ["qwen3-0.6b", "granite-moe-3b-a800m", "mamba2-370m", "hymba-1.5b",
         "qwen2-7b"]


def main() -> None:
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                    cfg.vocab_size)
        t0 = time.time()
        toks = generate(cfg, params["frozen"], params["lora"], prompt,
                        max_new=12, temperature=0.8,
                        key=jax.random.PRNGKey(2))
        dt = time.time() - t0
        print(f"{arch:24s} [{cfg.family:6s}] generated {toks.shape} "
              f"in {dt:5.1f}s  sample={toks[0, :6].tolist()}")

    # embeds-mode arch: frontend stub provides frame embeddings; decode then
    # feeds generated *tokens* through the decoder's own embedding table
    cfg = get_config("musicgen-large").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    cache = M.init_cache(cfg, 1, 16)
    frame = jax.random.normal(jax.random.PRNGKey(3), (1, 1, cfg.d_model),
                              jnp.float32) * 0.02
    logits, cache = M.decode_step(params["frozen"], params["lora"], cache,
                                  frame, jnp.int32(0), cfg)
    print(f"{'musicgen-large':24s} [audio ] one decode step from a frame "
          f"embedding -> logits {logits.shape}")


def continuous_batching_demo() -> None:
    """vLLM-style continuous batching over the cached decode path."""
    import numpy as np
    from repro.serving import Request, ServingEngine

    cfg = get_config("qwen3-0.6b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params["frozen"], params["lora"], slots=3,
                        max_len=64)
    rng = np.random.default_rng(0)
    for i in range(6):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 4 + i,
                                               dtype=np.int32),
                           max_new=8))
    stats = eng.run_until_drained()
    print(f"continuous batching: {stats['completed']} reqs, "
          f"{stats['tokens']} tokens in {stats['ticks']} ticks "
          f"({stats['tokens_per_sec']:.1f} tok/s CPU, "
          f"ttft {stats['mean_ttft_s']:.2f}s)")


if __name__ == "__main__":
    main()
    continuous_batching_demo()
