"""Quickstart: split LoRA fine-tuning of a tiny LLaMA on one device pair.

Walks the paper's loop end to end on CPU in ~a minute:
  1. "pre-train" a tiny backbone (stands in for the pre-trained LLM),
  2. CARD picks (cut layer, server frequency) from a live channel draw,
  3. run split fine-tuning (device stage | compressed channel | server
     stage) for a few rounds and watch the loss drop.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import get_config
from repro.core.card import card
from repro.core.channel import WirelessChannel
from repro.core.cost_model import RoundContext, Workload
from repro.core.hardware import EDGE_FLEET, SERVER_RTX4060TI, SimParams
from repro.core.protocol import SplitFineTuner
from repro.data import make_fleet_datasets
from repro.launch.train import run_training
from repro.models import model as M
from repro.optim import adamw, constant_schedule


def main() -> None:
    print("== 1. pre-train a tiny backbone (the 'pre-trained LLM') ==")
    pre = run_training(arch="llama32-1b", steps=0, pretrain_steps=80,
                       batch=8, seq_len=64, log_every=0)
    cfg, frozen = pre["cfg"], pre["frozen"]
    print(f"   backbone loss after pretraining: {pre['pretrain_loss']:.3f}")

    print("== 2. CARD decision for device1 under a 'normal' channel ==")
    sim = SimParams(local_epochs=2, mini_batch=8, seq_len=64)
    ctx = RoundContext(
        workload=Workload(get_config("llama32-1b"), sim.mini_batch,
                          sim.seq_len),
        device=EDGE_FLEET[0], server=SERVER_RTX4060TI,
        channel=WirelessChannel("normal", seed=0).draw(), sim=sim)
    d = card(ctx)
    print(f"   cut={d.cut}  f*={d.frequency / 1e9:.2f} GHz  "
          f"delay={d.delay:.2f}s  server energy={d.energy:.1f}J")

    print("== 3. split fine-tuning, 2 devices x 6 rounds ==")
    lora = M.init_params(jax.random.PRNGKey(1), cfg)["lora"]
    ft = SplitFineTuner(
        cfg, frozen, lora, adamw(constant_schedule(3e-3)),
        cost_cfg=get_config("llama32-1b"),
        devices=list(EDGE_FLEET[:2]), server=SERVER_RTX4060TI,
        channels=[WirelessChannel("normal", seed=i) for i in range(2)],
        datasets=make_fleet_datasets(cfg, 2, vocab=cfg.vocab_size, seed=1),
        sim=sim, policy="card")
    res = ft.run(6)
    losses = res.losses()
    print(f"   loss: {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} device-rounds")
    print(f"   simulated mean delay {res.mean_delay():.2f}s, "
          f"server energy {res.mean_energy():.1f}J")
    print("   cuts chosen:", sorted({l.cut for l in res.logs}))


if __name__ == "__main__":
    main()
