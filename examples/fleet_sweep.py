"""Fleet sweep: CARD decisions for 1000 heterogeneous edge devices at once.

The paper's target is "massive mobile devices"; the vectorized engine makes
that a sub-second interactive sweep rather than an overnight loop:

  1. build a 1000-device heterogeneous fleet (Table-I platforms, jittered
     DVFS clocks),
  2. draw every (round, device) channel state up front,
  3. run batched CARD (one jitted argmin over the cost tensor) per channel
     regime, and
  4. report cut mix, frequency spread, and exact parallel-SL round times.

    PYTHONPATH=src python examples/fleet_sweep.py
"""
import numpy as np

from repro.configs.base import get_config
from repro.core.hardware import make_heterogeneous_fleet
from repro.core.scheduler import parallel_round_stats, simulate_fleet


def main() -> None:
    cfg = get_config("llama32-1b")
    fleet = make_heterogeneous_fleet(1000, seed=0)
    print(f"== fleet of {len(fleet)} devices, {cfg.name}, 5 rounds/state ==")
    for state in ("good", "normal", "poor"):
        log = simulate_fleet(cfg, channel_state=state, rounds=5,
                             devices=fleet, seed=0)
        offload = float((log.cuts == 0).mean())
        local = float((log.cuts == cfg.n_layers).mean())
        stats = parallel_round_stats(log)
        print(f"  {state:>6}: full-offload {offload:5.1%}  "
              f"full-local {local:5.1%}  "
              f"f* {log.freqs.mean() / 1e9:.2f}±{log.freqs.std() / 1e9:.2f} GHz")
        print(f"          round delay {log.mean_delay():8.2f}s seq-equiv | "
              f"parallel-SL exact {stats['parallel_exact_s']:8.2f}s "
              f"(bounds [{stats['parallel_lower_s']:.2f}, "
              f"{stats['parallel_upper_s']:.2f}])")
        print(f"          server energy {log.mean_energy():8.1f} J/device-round")


if __name__ == "__main__":
    main()
